"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref oracle.

(`hypothesis` is not installable offline; sweeps are seeded parameterized
grids + randomized draws per cell — see also tests/test_property.py.)
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fixed_point import to_fixed
from repro.core.lut import build_sigmoid_lut
from repro.kernels.pallas_compat import HAS_PALLAS

# this file validates the Pallas kernels themselves; without Pallas the
# ops wrappers degrade to jnp_ref and every case would pass vacuously
pytestmark = pytest.mark.skipif(
    not HAS_PALLAS, reason="this jax build has no Pallas "
    "(dispatch degrades to jnp_ref; nothing to validate here)")

# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
from repro.kernels.quant_matmul.kernel import int_matmul
from repro.kernels.quant_matmul.ops import quant_dense, quant_matmul
from repro.kernels.quant_matmul.ref import int_matmul_ref, quant_matmul_ref


slow = pytest.mark.slow  # large-shape interpret-mode cases (tier-1 only)


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 128, 128, 128, 128, 128),   # single block
    pytest.param(256, 384, 128, 128, 128, 128,
                 marks=slow),         # multi-block all dims
    (64, 64, 64, 32, 16, 64),         # small, odd block ratios
    (8, 256, 8, 8, 64, 8),            # skinny
])
def test_int_matmul_exact(m, k, n, bm, bk, bn):
    rng = np.random.RandomState(m + n + k)
    a = jnp.asarray(rng.randint(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.randint(-128, 128, (k, n)), jnp.int8)
    out = int_matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=True)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(int_matmul_ref(a, b)))


@pytest.mark.parametrize("scale_kind", ["scalar", "per_channel"])
def test_quant_matmul_dequant(scale_kind):
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randint(-128, 128, (64, 128)), jnp.int8)
    b = jnp.asarray(rng.randint(-128, 128, (128, 64)), jnp.int8)
    sa = jnp.float32(0.01)
    sb = (jnp.float32(0.02) if scale_kind == "scalar"
          else jnp.asarray(rng.uniform(0.01, 0.05, (1, 64)), jnp.float32))
    out = quant_matmul(a, b, sa, sb, use_pallas=True, interpret=True)
    ref = quant_matmul_ref(a, b, sa, sb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_dense_accuracy(dtype):
    """Quantized dense must track the float matmul within int8 error."""
    from repro.core.quantization import symmetric_quantize
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.normal(0, 1, (32, 256)), dtype)
    w = jnp.asarray(rng.normal(0, 0.05, (256, 128)), jnp.float32)
    wq, wp = symmetric_quantize(w, bits=8, axis=1)
    out = quant_dense(x, wq, wp.scale, use_pallas=True, interpret=True)
    ref = x.astype(jnp.float32) @ w
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref))
    rel = err.max() / max(float(np.abs(np.asarray(ref)).max()), 1e-9)
    assert rel < 0.05


# ---------------------------------------------------------------------------
# lut_activation
# ---------------------------------------------------------------------------
from repro.kernels.lut_activation.ops import lut_sigmoid
from repro.kernels.lut_activation.ref import lut_sigmoid_ref


@pytest.mark.parametrize("shape", [(7,), (100,), (33, 5), (256, 128)])
@pytest.mark.parametrize("frac_bits", [8, 10])
def test_lut_sigmoid_kernel_matches_ref(shape, frac_bits):
    lut = build_sigmoid_lut(boundary=20, frac_bits=frac_bits)
    rng = np.random.RandomState(sum(shape))
    x = jnp.asarray(rng.uniform(-25, 25, shape), jnp.float32)
    xq = to_fixed(x, frac_bits)
    out = lut_sigmoid(xq, lut, placement="vmem")
    ref = lut_sigmoid_ref(xq, lut.table, lut.value_frac)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_lut_sigmoid_placements_identical():
    """Paper §5.2.2: WRAM vs MRAM placement is performance-only."""
    lut = build_sigmoid_lut()
    xq = to_fixed(jnp.linspace(-20, 20, 999), 10)
    np.testing.assert_array_equal(
        np.asarray(lut_sigmoid(xq, lut, placement="vmem")),
        np.asarray(lut_sigmoid(xq, lut, placement="hbm")))


# ---------------------------------------------------------------------------
# kmeans_assign
# ---------------------------------------------------------------------------
from repro.kernels.kmeans_assign.ops import assign_and_accumulate
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref


@pytest.mark.parametrize("n,f,k,bn", [
    pytest.param(1024, 16, 16, 256, marks=slow),
    (1000, 16, 16, 256),    # padding path
    (128, 8, 4, 128),
    pytest.param(512, 32, 64, 64, marks=slow),
])
def test_kmeans_assign_matches_ref(n, f, k, bn):
    rng = np.random.RandomState(n + k)
    x = jnp.asarray(rng.randint(-2047, 2048, (n, f)), jnp.int16)
    c = jnp.asarray(rng.randint(-2047, 2048, (k, f)), jnp.int16)
    l1, s1, n1 = assign_and_accumulate(x, c, use_pallas=True, block_n=bn)
    l2, s2, n2 = kmeans_assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    assert int(n1.sum()) == n


@slow
def test_kmeans_assign_int32_exactness_bound():
    """Quantization range choice guarantees exact int32 accumulation
    (DESIGN.md §2): max |coord| * N_per_cluster must fit in int31."""
    n, f, k = 4096, 16, 2
    x = jnp.full((n, f), 2047, jnp.int16)
    c = jnp.asarray(np.stack([np.full(f, 2047), np.full(f, -2047)]),
                    jnp.int16)
    _, sums, counts = assign_and_accumulate(x, c, use_pallas=True,
                                            block_n=1024)
    assert int(counts[0]) == n
    assert int(sums[0, 0]) == 2047 * n  # exact, no overflow


# ---------------------------------------------------------------------------
# gini_split
# ---------------------------------------------------------------------------
from repro.kernels.gini_split.ops import split_evaluate
from repro.kernels.gini_split.ref import gini_counts_ref


@pytest.mark.parametrize("n,f,L,C,bn", [
    pytest.param(1024, 16, 8, 2, 256, marks=slow),
    (1000, 16, 8, 2, 256),   # padding path
    pytest.param(512, 4, 32, 4, 128, marks=slow),    # multiclass
    (100, 1, 1, 2, 100),     # single feature/leaf
])
def test_gini_split_matches_ref(n, f, L, C, bn):
    rng = np.random.RandomState(n + L)
    x = jnp.asarray(rng.uniform(0, 1, (n, f)), jnp.float32)
    y = jnp.asarray(rng.randint(0, C, n), jnp.int32)
    leaf = jnp.asarray(rng.randint(0, L, n), jnp.int32)
    th = jnp.asarray(rng.uniform(0, 1, (L, f)), jnp.float32)
    b1, t1 = split_evaluate(x, y, leaf, th, C, use_pallas=True, block_n=bn)
    b2, t2 = gini_counts_ref(x, y, leaf, th, C)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert int(t1.sum()) == n


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64),
                                     pytest.param(256, 128, 64, marks=slow),
                                     (64, 64, 64)])
def test_flash_causal_matches_ref(dtype, s, bq, bk):
    rng = np.random.RandomState(s)
    q = jnp.asarray(rng.normal(0, 1, (2, 4, s, 64)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (2, 4, s, 64)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (2, 4, s, 64)), dtype)
    out = mha(q, k, v, causal=True, use_pallas=True, bq=bq, bk=bk)
    ref = mha(q, k, v, causal=True, use_pallas=False)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_gqa_and_noncausal():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.normal(0, 1, (1, 8, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.float32)
    for causal in (True, False):
        out = mha(q, k, v, causal=causal, use_pallas=True, bq=64, bk=64)
        ref = mha(q, k, v, causal=causal, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6)


@slow
def test_flash_decode_one_token():
    """serve_step shape: 1 query against a long KV cache."""
    rng = np.random.RandomState(9)
    skv = 512
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 4, skv, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 4, skv, 64)), jnp.float32)
    out = mha(q, k, v, causal=True, q_offset=skv - 1, use_pallas=True,
              bq=1, bk=128)
    ref = mha(q, k, v, causal=True, q_offset=skv - 1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("window,s,bq,bk", [
    pytest.param(32, 256, 64, 64, marks=slow),
    (64, 128, 64, 64), (1, 128, 64, 64),
    pytest.param(100, 256, 128, 64, marks=slow),
])
def test_flash_sliding_window_matches_ref(window, s, bq, bk):
    """SWA path (hymba): out-of-window kv blocks are skipped entirely."""
    rng = np.random.RandomState(window + s)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, s, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 4, s, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 4, s, 32)), jnp.float32)
    out = mha(q, k, v, causal=True, window=window, use_pallas=True,
              bq=bq, bk=bk)
    ref = mha(q, k, v, causal=True, window=window, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_window_decode():
    """Windowed single-token decode against a long cache."""
    rng = np.random.RandomState(3)
    skv = 256
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, skv, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, skv, 32)), jnp.float32)
    out = mha(q, k, v, causal=True, q_offset=skv - 1, window=64,
              use_pallas=True, bq=1, bk=64)
    ref = mha(q, k, v, causal=True, q_offset=skv - 1, window=64,
              use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
