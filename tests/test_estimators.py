"""sklearn-style estimator facade (paper §4: scikit-learn compatibility)."""
import numpy as np

from repro.core.estimators import (PimDecisionTreeClassifier, PimKMeans,
                                   PimLinearRegression,
                                   PimLogisticRegression)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def test_linear_regression_estimator():
    X, y, _ = make_linear_dataset(2048, 8, task="regression", seed=0)
    est = PimLinearRegression(version="int32", n_iters=400).fit(X, y)
    assert est.score(X, y) > 0.95
    assert est.coef_.shape == (8,)


def test_logistic_regression_estimator():
    X, y, _ = make_linear_dataset(2048, 8, seed=1)
    est = PimLogisticRegression(version="int32_lut_wram",
                                n_iters=400).fit(X, y)
    assert est.score(X, y) > 0.95
    proba = est.predict_proba(X[:10])
    assert proba.shape == (10, 2)
    assert np.allclose(proba.sum(axis=1), 1.0, atol=1e-5)


def test_decision_tree_estimator():
    X, y = make_classification(8000, 16, seed=3, class_sep=1.5)
    est = PimDecisionTreeClassifier(max_depth=8, seed=0).fit(X, y)
    assert est.score(X, y) > 0.75


def test_kmeans_estimator():
    X, _, _ = make_blobs(6000, 8, centers=8, seed=4)
    est = PimKMeans(n_clusters=8, n_init=2, seed=0).fit(X)
    assert est.cluster_centers_.shape == (8, 8)
    assert est.labels_.shape == (6000,)
    pred = est.predict(X[:100])
    assert np.array_equal(pred, est.labels_[:100])


def test_estimators_duck_type_sklearn():
    """fit returns self; predict/score exist (pipeline compatibility)."""
    X, y, _ = make_linear_dataset(512, 4, seed=5)
    for est in (PimLinearRegression(n_iters=10),
                PimLogisticRegression(n_iters=10)):
        assert est.fit(X, y) is est
        assert est.predict(X).shape[0] == 512
        assert np.isfinite(est.score(X, y))
