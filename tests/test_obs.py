"""Unified telemetry layer (repro/obs; DESIGN.md §13).

Covers the span tracer (overhead contract included), the Chrome
trace-event exporter (schema validity, nesting, determinism under a
seeded manifest), the metrics registry (snapshot/delta + parent
mirroring, per-job attribution across PimSlice/HostSlice/GpuModelSlice),
drift accounting in ``PimScheduler.stats()``, the shared CLI table
formatter, and the run-metadata envelope.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.data.synthetic import make_linear_dataset
from repro.obs import (DRIFT_BUCKETS, TRACER, Column, Counter, Histogram,
                       MetricsRegistry, format_ratio, load_chrome_trace,
                       render_table, run_meta, to_chrome_trace,
                       track_names, validate_chrome_trace, write_json)
from repro.obs.trace import NULL_SPAN, Tracer
from repro.sched import JobState, PimScheduler, run_manifest
from repro.api import make_system


@pytest.fixture
def tracer():
    """The global tracer, enabled and clean, restored afterwards."""
    TRACER.clear()
    TRACER.enable()
    yield TRACER
    TRACER.disable()
    TRACER.clear()


def _small_manifest(n_iters=12):
    return {
        "system": {"cores": 8, "rank_size": 4},
        "datasets": {"lin": {"kind": "linear", "samples": 256,
                             "features": 8, "seed": 0}},
        "jobs": [
            {"workload": "linreg", "dataset": "lin", "cores": 4,
             "version": "int32", "params": {"n_iters": n_iters}},
            {"workload": "logreg", "dataset": "lin", "cores": 4,
             "version": "int32", "params": {"n_iters": n_iters}},
        ],
    }


# ---------------------------------------------------------------------------
# Span tracer.
# ---------------------------------------------------------------------------

def test_tracer_disabled_emits_nothing_and_shares_null_span():
    t = Tracer()
    assert not t.enabled
    span = t.span("x", track="a")
    assert span is NULL_SPAN          # one shared no-op, no allocation
    with span:
        pass
    t.instant("i")
    t.counter("c", 1.0)
    assert len(t) == 0


def test_tracer_records_spans_instants_counters():
    t = Tracer()
    t.enable()
    with t.span("outer", track="target:pim", cat="chunk", job="j0"):
        with t.span("inner", track="target:pim"):
            pass
    t.instant("preempt", track="job:j0", cat="elastic")
    t.counter("channel0.occupancy", 0.5, track="channels:pim")
    events = t.events()
    assert [e["ph"] for e in events] == ["X", "X", "i", "C"]
    # spans append on exit: inner closes before outer
    assert events[0]["name"] == "inner"
    assert events[1]["name"] == "outer"
    assert events[1]["args"] == {"job": "j0"}
    outer, inner = events[1], events[0]
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert events[3]["args"] == {"value": 0.5}


def test_tracer_ring_buffer_drops_oldest():
    t = Tracer(capacity=4)
    t.enable()
    for i in range(10):
        t.instant(f"e{i}")
    names = [e["name"] for e in t.events()]
    assert names == ["e6", "e7", "e8", "e9"]


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_snapshot_delta():
    reg = MetricsRegistry()
    c = reg.counter("launches")
    c.inc(3)
    snap = reg.snapshot()
    c.inc(2)
    reg.gauge("occupancy").set(0.75)
    h = reg.histogram("ratio", bounds=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    delta = reg.delta(snap)
    assert delta["launches"] == 2
    assert h.buckets == [1, 1, 1]
    assert h.count == 3 and h.min == 0.5 and h.max == 50.0
    assert h.mean == pytest.approx(55.5 / 3)
    # registry-level dict stays JSON-serializable
    json.dumps(reg.to_dict())


def test_histogram_delta_is_bucketwise():
    h = Histogram(bounds=(1.0, 2.0))
    h.observe(0.5)
    snap = h.snapshot()
    h.observe(1.5)
    h.observe(5.0)
    d = h.delta(snap)
    assert d["count"] == 2 and d["buckets"] == [0, 1, 1]


def test_registry_parent_mirroring():
    parent = MetricsRegistry()
    a, b = MetricsRegistry(parent=parent), MetricsRegistry(parent=parent)
    a.counter("x").inc(3)
    b.counter("x").inc(4)
    a.histogram("h").observe(1.0)
    b.histogram("h").observe(2.0)
    assert parent.counter("x").value == 7
    assert parent.histogram("h").count == 2
    # children stay attributable
    assert a.counter("x").value == 3 and b.counter("x").value == 4


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_counter_standalone_parent():
    parent = Counter()
    child = Counter(parent=parent)
    child.inc(5)
    snap = child.snapshot()
    child.inc(2)
    assert child.delta(snap) == 2 and parent.value == 7


# ---------------------------------------------------------------------------
# Thread safety: the serve-mode drain thread increments metrics
# concurrently with caller-thread reads (DESIGN.md §14.2) — mirrored
# increments must never be lost or double-propagated.
# ---------------------------------------------------------------------------

def test_concurrent_mirrored_counter_increments_are_exact():
    import threading

    parent = MetricsRegistry()
    n_threads, n_incs = 8, 2000
    children = [MetricsRegistry(parent=parent) for _ in range(n_threads)]
    # pre-create so every thread races on the SAME counter objects
    for child in children:
        child.counter("sched.steps")

    def work(child):
        c = child.counter("sched.steps")
        h = child.histogram("sched.step_seconds", bounds=(1.0, 10.0))
        for i in range(n_incs):
            c.inc()
            h.observe(float(i % 3))

    threads = [threading.Thread(target=work, args=(c,))
               for c in children]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert parent.counter("sched.steps").value == n_threads * n_incs
    hist = parent.histogram("sched.step_seconds", bounds=(1.0, 10.0))
    assert hist.count == n_threads * n_incs
    assert sum(hist.buckets) == hist.count
    for child in children:
        assert child.counter("sched.steps").value == n_incs


def test_concurrent_registry_lazy_creation_single_instance():
    import threading

    reg = MetricsRegistry()
    out = [None] * 16
    barrier = threading.Barrier(len(out))

    def grab(i):
        barrier.wait()
        out[i] = reg.counter("lazy.race")

    threads = [threading.Thread(target=grab, args=(i,))
               for i in range(len(out))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(c is out[0] for c in out)


def test_concurrent_mirror_stats_increments_are_exact():
    import threading

    from repro.systems.base import TransferStats, _MirrorStats

    parent = TransferStats()
    n_threads, n_incs = 8, 2000
    mirrors = [_MirrorStats(parent) for _ in range(n_threads)]
    stop = threading.Event()

    def bump(m):
        for _ in range(n_incs):
            m.cpu_to_pim += 3
            m.host_syncs += 1

    def read():
        # caller-thread stats() reads must never crash or tear while
        # the drain thread mirrors increments
        while not stop.is_set():
            snap = parent.snapshot()
            assert snap.cpu_to_pim >= 0

    threads = [threading.Thread(target=bump, args=(m,)) for m in mirrors]
    reader = threading.Thread(target=read)
    reader.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    reader.join()
    assert parent.cpu_to_pim == n_threads * n_incs * 3
    assert parent.host_syncs == n_threads * n_incs
    for m in mirrors:
        assert m.cpu_to_pim == n_incs * 3


# ---------------------------------------------------------------------------
# Per-slice attribution: parent totals == sum of per-job deltas in a
# mixed-target queue (PimSlice / HostSlice / GpuModelSlice).
# ---------------------------------------------------------------------------

def test_mixed_target_parent_totals_equal_job_delta_sums():
    X, y, _ = make_linear_dataset(192, 6, seed=0)
    systems = {"pim": make_system("pim", n_cores=8),
               "host": make_system("host", n_cores=4),
               "gpu": make_system("gpu-model", n_cores=4)}
    sched = PimScheduler(systems, rank_size=4)
    handles = []
    for target, version in (("pim", "int32"), ("host", "fp32"),
                            ("gpu", "fp32")):
        handles.append(sched.submit(
            "linreg", (X, y), version=version, n_cores=4,
            target=target, n_iters=10))
        handles.append(sched.submit(
            "logreg", (X, y), version=version, n_cores=4,
            target=target, n_iters=10))
    sched.drain()
    assert all(h.state is JobState.DONE for h in handles)
    for target, system in systems.items():
        jobs = [h for h in handles if h.target == target]
        assert all(h.transfer is not None for h in jobs)
        for field in ("kernel_launches", "cpu_to_pim", "pim_to_cpu",
                      "shard_transfers", "shard_bytes", "dram_bytes"):
            total = getattr(system.stats, field)
            attributed = sum(getattr(h.transfer, field) for h in jobs)
            assert attributed == total, (target, field)
    # the modeled-GPU roofline mirrors per slice the same way
    gpu_jobs = [h for h in handles if h.target == "gpu"]
    assert all(h.gpu is not None for h in gpu_jobs)
    assert sum(h.gpu.launches for h in gpu_jobs) \
        == systems["gpu"].gpu.launches
    assert sum(h.gpu.modeled_seconds for h in gpu_jobs) \
        == pytest.approx(systems["gpu"].gpu.modeled_seconds)


# ---------------------------------------------------------------------------
# Chrome trace export.
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_and_tracks():
    t = Tracer()
    t.enable()
    with t.span("chunk", track="target:pim"):
        pass
    t.instant("preempt", track="job:j0")
    t.counter("channel0.occupancy", 1.0, track="channels:pim")
    doc = to_chrome_trace(t.events())
    validate_chrome_trace(doc)
    assert track_names(doc) == {"target:pim", "job:j0", "channels:pim"}
    body = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert {e["ph"] for e in body} == {"X", "i", "C"}
    for ev in body:
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
    # groups map to distinct pids, tracks to distinct (pid, tid) rows
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    groups = {e["args"]["name"] for e in meta
              if e["name"] == "process_name"}
    assert groups == {"target", "job", "channels"}


def test_validate_rejects_malformed_docs():
    with pytest.raises(ValueError):
        validate_chrome_trace({})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "a",
                                               "pid": 1, "tid": 1,
                                               "ts": 0.0}]})  # no dur
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "a",
                                               "pid": 1, "tid": "x",
                                               "ts": 0.0, "dur": 1.0}]})
    # overlapping (non-nesting) spans on one row
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": 10.0},
        {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 5.0,
         "dur": 10.0},
    ]}
    with pytest.raises(ValueError, match="overlaps"):
        validate_chrome_trace(bad)


def test_chrome_trace_roundtrip_and_write(tmp_path, tracer):
    with tracer.span("s", track="a"):
        pass
    path = os.path.join(str(tmp_path), "trace.json")
    from repro.obs import write_chrome_trace
    doc = write_chrome_trace(tracer.events(), path)
    assert load_chrome_trace(path) == doc
    validate_chrome_trace(doc)


def _traced_manifest_signature():
    TRACER.clear()
    TRACER.enable()
    try:
        run_manifest(_small_manifest())
        return [(e["ph"], e["name"], e["track"]) for e in TRACER.events()]
    finally:
        TRACER.disable()
        TRACER.clear()


def test_trace_deterministic_under_seeded_manifest():
    first = _traced_manifest_signature()
    second = _traced_manifest_signature()
    assert first == second
    assert first        # actually traced something
    tracks = {t for _, _, t in first}
    assert "sched" in tracks
    assert any(t.startswith("job:") for t in tracks)
    assert any(t.startswith("channels:") for t in tracks)


def test_scheduler_trace_has_expected_tracks_and_spans(tracer):
    scheduler, handles = run_manifest(_small_manifest())
    assert all(h.state is JobState.DONE for h in handles)
    doc = to_chrome_trace(tracer.events())
    validate_chrome_trace(doc)
    tracks = track_names(doc)
    assert "sched" in tracks and "target:pim" in tracks
    assert "channels:pim" in tracks
    assert any(t.startswith("job:") for t in tracks)
    body = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    names = {e["name"] for e in body}
    assert "chunk" in names and "admit" in names
    assert any(n.startswith("channel") for n in names)   # occupancy
    assert any(n.startswith("map_reduce:") or n.startswith("chunk:")
               for n in names)                            # launch spans


def test_preempt_resume_instants_in_trace(tracer):
    X, y, _ = make_linear_dataset(256, 8, seed=1)
    sched = PimScheduler(make_system("pim", n_cores=8), rank_size=4)
    h = sched.submit("linreg", (X, y), version="int32", n_cores=4,
                     n_iters=30)
    sched.step()
    sched.step()
    h.preempt()
    sched.step()
    assert h.state is JobState.PREEMPTED
    sched.resume(h)
    sched.drain()
    assert h.state is JobState.DONE
    instants = [e["name"] for e in tracer.events() if e["ph"] == "i"
                and e["track"] == f"job:{h.name}"]
    assert "preempt" in instants and "resume" in instants
    doc = to_chrome_trace(tracer.events())
    validate_chrome_trace(doc)
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert {"preempt", "resume"} <= {e["name"] for e in inst}


# ---------------------------------------------------------------------------
# Drift accounting.
# ---------------------------------------------------------------------------

def test_stats_reports_per_job_drift_ratios():
    scheduler, handles = run_manifest(_small_manifest())
    stats = scheduler.stats()
    json.dumps(stats)                      # whole surface serializes
    drift = stats["drift"]
    assert set(drift) == {h.name for h in handles}
    for h in handles:
        entry = drift[h.name]
        assert entry["ratio"] is not None and entry["ratio"] > 0
        assert entry["chunks"] == h.drift.count > 0
        assert entry["measured_seconds"] == h.measured_seconds > 0
        assert h.drift_ratio == pytest.approx(
            h.measured_seconds / h.modeled_seconds)
    # the scheduler-wide per-chunk histogram saw every priced chunk
    hist = stats["metrics"]["sched.drift_ratio"]
    assert hist["count"] == sum(h.drift.count for h in handles)
    assert list(hist["bounds"]) == list(DRIFT_BUCKETS)
    # JobHandle.metrics() carries the same accounting per job
    m = handles[0].metrics()
    assert m["drift_ratio"] == handles[0].drift_ratio
    assert m["transfer"]["kernel_launches"] > 0


def test_drift_ratio_none_when_model_cannot_price():
    X, y, _ = make_linear_dataset(128, 4, seed=0)
    sched = PimScheduler(make_system("host", n_cores=4), rank_size=4)
    h = sched.submit("linreg", (X, y), version="fp32", n_cores=4,
                     n_iters=5)
    sched.drain()
    assert h.state is JobState.DONE
    assert h.modeled_seconds == 0.0
    assert h.drift_ratio is None           # absence, not a guess
    assert h.measured_seconds > 0.0


# ---------------------------------------------------------------------------
# Overhead contract: tracing disabled must cost <2% of a small
# scheduler sweep makespan.
# ---------------------------------------------------------------------------

def test_disabled_tracer_overhead_under_two_percent():
    assert not TRACER.enabled
    # the untraced baseline: a small scheduled sweep
    t0 = time.perf_counter()
    scheduler, handles = run_manifest(_small_manifest())
    makespan = time.perf_counter() - t0
    assert all(h.state is JobState.DONE for h in handles)
    # how many telemetry call sites would that drain hit when enabled?
    TRACER.clear()
    TRACER.enable()
    try:
        run_manifest(_small_manifest())
        n_sites = len(TRACER)
    finally:
        TRACER.disable()
        TRACER.clear()
    # per-call cost of the disabled fast path (one attribute check)
    n_calls = 50_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        TRACER.span("x", track="t")
        TRACER.instant("x")
        TRACER.counter("x", 1.0)
    per_site = (time.perf_counter() - t0) / (3 * n_calls)
    # deterministic guard: the disabled overhead the instrumented run
    # pays is (sites hit) x (disabled per-call cost) — far under 2%
    assert n_sites * per_site < 0.02 * makespan, (
        f"{n_sites} sites x {per_site * 1e9:.0f} ns "
        f"vs makespan {makespan:.3f}s")


# ---------------------------------------------------------------------------
# Shared CLI formatter.
# ---------------------------------------------------------------------------

def test_render_table_formats_and_defaults():
    cols = (Column("name", width=6, align="<"),
            Column("x", width=8, spec=".2f"),
            Column("n", width=4, spec="d", default="0"))
    out = render_table([{"name": "alpha", "x": 1.5, "n": 3},
                        {"name": "toolongname", "x": None}],
                       cols, extra=lambda r: r.get("err", ""))
    lines = out.splitlines()
    assert lines[0].split() == ["name", "x", "n"]
    assert lines[1].split() == ["alpha", "1.50", "3"]
    assert lines[2].split() == ["toolon", "-", "0"]   # clipped + defaults
    assert format_ratio(None) == "-"
    assert format_ratio(2.5) == "2.50x"
    assert format_ratio(1234.0) == "1234x"


def test_launch_cli_column_specs_cover_report_rows():
    from repro.launch.compare import COMPARE_COLUMNS
    from repro.launch.pim_jobs import JOB_COLUMNS
    assert {"name", "state", "drift_ratio"} <= {c.key for c in JOB_COLUMNS}
    assert {"workload", "drift_ratio"} <= {c.key for c in COMPARE_COLUMNS}


# ---------------------------------------------------------------------------
# Run-metadata envelope.
# ---------------------------------------------------------------------------

def test_run_meta_fields():
    meta = run_meta()
    assert set(meta) == {"git_sha", "git_dirty", "timestamp",
                         "jax_version", "python", "platform"}
    assert meta["timestamp"].endswith("+00:00")        # UTC ISO-8601
    assert meta["git_sha"] is None or len(meta["git_sha"]) == 40


def test_write_json_stamps_envelope(tmp_path):
    path = os.path.join(str(tmp_path), "out", "bench.json")
    stamped = write_json(path, {"metric": 1.0})
    on_disk = json.load(open(path))
    assert on_disk == stamped
    assert on_disk["metric"] == 1.0
    assert "timestamp" in on_disk["run_meta"]


def test_benchmarks_common_reexports_writer():
    from benchmarks.common import write_json as bench_writer
    assert bench_writer is write_json


# ---------------------------------------------------------------------------
# End-to-end CLI acceptance (slow tier).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pim_jobs_trace_flag_on_example_manifest(tmp_path):
    from repro.launch.pim_jobs import main
    trace_path = os.path.join(str(tmp_path), "trace.json")
    manifest = os.path.join(os.path.dirname(__file__), os.pardir,
                            "examples", "jobs.yaml")
    try:
        rc = main([manifest, "--trace", trace_path])
    finally:
        TRACER.disable()
        TRACER.clear()
    assert rc == 0
    doc = load_chrome_trace(trace_path)
    validate_chrome_trace(doc)
    tracks = track_names(doc)
    assert "channels:pim" in tracks            # per-channel rows
    assert any(t.startswith("job:") for t in tracks)   # per-job rows
    assert "target:pim" in tracks


@pytest.mark.slow
def test_repro_trace_env_var_exports_on_exit(tmp_path):
    trace_path = os.path.join(str(tmp_path), "env_trace.json")
    env = dict(os.environ,
               REPRO_TRACE=trace_path,
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    code = ("from repro.obs import TRACER\n"
            "assert TRACER.enabled\n"
            "with TRACER.span('s', track='t'):\n"
            "    pass\n")
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   cwd=os.path.join(os.path.dirname(__file__), os.pardir))
    doc = load_chrome_trace(trace_path)
    validate_chrome_trace(doc)
    assert track_names(doc) == {"t"}
