"""Per-architecture smoke tests (deliverable (f)).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train-gradient step on CPU, asserting output shapes
and finiteness.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.api import Model

B, S = 2, 32


def _batch(cfg, seed=0):
    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "targets": tokens}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_tokens, cfg.vision_dim)), dt)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)), dt)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """One decode step after prefill must equal the teacher-forced
    forward's last-position logits (cache correctness across families)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, seed=1)
    tokens = batch["tokens"]

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    lg_pre, cache = model.prefill(params, pre_batch, max_seq=S)
    extras = ({"cross_states": batch["vision"]}
              if cfg.family == "vlm" else None)
    lg_dec, _ = model.decode_step(params, tokens[:, -1:], cache, extras)
    full = model.forward(params, batch)
    tol = 1e-3 if cfg.dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-moe-a2.7b",
                                  "xlstm-350m", "hymba-1.5b"])
def test_two_train_steps_reduce_loss(arch):
    """SGD on repeated batch must reduce loss (end-to-end trainability)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, seed=2)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: model.loss(q, batch))(p)
        p = jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw.astype(
            w.dtype), p, g)
        return p, loss

    losses = []
    for _ in range(5):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark
    (computed via eval_shape — no allocation)."""
    from repro.models.transformer import count_params
    expected = {
        "dbrx-132b": (110e9, 165e9),
        "qwen2.5-32b": (28e9, 40e9),
        "qwen3-8b": (7e9, 10.5e9),
        "granite-3-8b": (7e9, 10e9),
        "stablelm-12b": (10e9, 15e9),
        "qwen2-moe-a2.7b": (12e9, 18e9),   # total (not active) params
        "xlstm-350m": (0.25e9, 0.6e9),
        "hymba-1.5b": (1.2e9, 2.3e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")
