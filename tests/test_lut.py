import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fixed_point import from_fixed, to_fixed
from repro.core.lut import (ActivationLut, build_sigmoid_lut, gelu_lut,
                            lut_sigmoid_fixed, lut_sigmoid_float, silu_lut,
                            taylor_sigmoid_fixed)


def test_lut_size_matches_paper():
    """Paper Fig. 4: boundary 20, 10 frac bits, 16-bit entries -> 40 KB."""
    lut = build_sigmoid_lut(boundary=20, frac_bits=10)
    assert lut.table.size == 20 * 1024
    assert lut.nbytes == 40 * 1024
    assert lut.table.dtype == jnp.int16


def test_lut_sigmoid_accuracy():
    lut = build_sigmoid_lut()
    x = jnp.linspace(-15, 15, 4001)
    err = np.abs(np.asarray(lut_sigmoid_float(x, lut))
                 - np.asarray(jax.nn.sigmoid(x)))
    assert err.max() < 5e-4  # Q10 input / Q15 value resolution


def test_lut_sigmoid_symmetry():
    """sigmoid(-x) = 1 - sigmoid(x) must hold exactly (paper exploits it)."""
    lut = build_sigmoid_lut()
    xq = to_fixed(jnp.linspace(0.01, 19, 257), 10)
    pos = lut_sigmoid_fixed(xq, lut)
    neg = lut_sigmoid_fixed(-xq, lut)
    one = 1 << lut.value_frac
    assert np.array_equal(np.asarray(pos + neg), np.full(257, one))


def test_taylor_sigmoid_worse_than_lut():
    """Paper §5.1.2: Taylor versions have higher error than LUT versions."""
    lut = build_sigmoid_lut()
    x = jnp.linspace(-10, 10, 2001)
    xq = to_fixed(x, 10)
    ref = np.asarray(jax.nn.sigmoid(x))
    lut_err = np.abs(np.asarray(from_fixed(lut_sigmoid_fixed(xq, lut), 15))
                     - ref).max()
    tay_err = np.abs(np.asarray(from_fixed(taylor_sigmoid_fixed(xq, 10), 10))
                     - ref).max()
    assert tay_err > lut_err
    assert tay_err < 0.05  # still usable (paper's LOG-INT32 trains OK)


@pytest.mark.parametrize("make,fn", [
    (silu_lut, lambda x: x / (1 + np.exp(-x))),
    (gelu_lut, lambda x: 0.5 * x * (1 + np.tanh(
        np.sqrt(2 / np.pi) * (x + 0.044715 * x ** 3)))),
])
def test_activation_luts(make, fn):
    lut = make(n_entries=8192)
    x = jnp.linspace(-10, 10, 1001).astype(jnp.float32)
    out = np.asarray(lut(x))
    assert np.abs(out - fn(np.asarray(x))).max() < 2e-2


def test_activation_lut_clamps_out_of_range():
    lut = ActivationLut.from_fn(lambda x: x, x_min=-1, x_max=1, n_entries=256)
    out = np.asarray(lut(jnp.asarray([-5.0, 5.0])))
    assert out[0] == -1.0 and out[1] == 1.0
