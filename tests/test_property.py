"""Property-based tests (seeded random sweeps).

`hypothesis` cannot be installed in this offline container; these tests
randomize shapes/values over seeded draws and assert system invariants —
the same falsification intent, deterministic by construction.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fixed_point import from_fixed, fx_dot, to_fixed
from repro.core.lut import build_sigmoid_lut, lut_sigmoid_fixed
from repro.core.pim import PimConfig, PimSystem
from repro.core.quantization import dequantize, symmetric_quantize

N_CASES = 25


def _cases(seed, n=N_CASES):
    return [np.random.RandomState(seed + i) for i in range(n)]


def test_quantization_error_bound_property():
    """|x - dq(q(x))| <= scale/2 for every tensor, any shape/range."""
    for rng in _cases(0):
        shape = tuple(rng.randint(1, 24, size=rng.randint(1, 4)))
        scale = 10.0 ** rng.uniform(-3, 3)
        x = jnp.asarray(rng.uniform(-scale, scale, shape), jnp.float32)
        bits = int(rng.choice([8, 16]))
        q, p = symmetric_quantize(x, bits=bits)
        err = jnp.abs(dequantize(q, p) - x)
        # + f32 rounding slack: x/scale and q*scale are f32 ops
        tol = float(p.scale) * 0.5 + float(jnp.abs(x).max()) * 1e-6
        assert float(err.max()) <= tol


def test_fx_dot_linearity_property():
    """fx_dot(a*x, w) ~= a*fx_dot(x, w) for integer scalings."""
    for rng in _cases(10):
        f = int(rng.choice([8, 10, 12]))
        n = rng.randint(2, 32)
        x = rng.uniform(0, 1, n).astype(np.float32)
        w = rng.uniform(-1, 1, n).astype(np.float32)
        d1 = float(from_fixed(fx_dot(to_fixed(x, f), to_fixed(w, f), f), f))
        d2 = float(from_fixed(fx_dot(to_fixed(2 * x, f),
                                     to_fixed(w, f), f), f))
        assert abs(d2 - 2 * d1) < n * 2.0 ** -f * 8 + 1e-6


def test_lut_sigmoid_monotone_and_bounded_property():
    lut = build_sigmoid_lut()
    for rng in _cases(20, 10):
        x = np.sort(rng.uniform(-30, 30, 64)).astype(np.float32)
        out = np.asarray(lut_sigmoid_fixed(to_fixed(x, 10), lut))
        assert (np.diff(out) >= 0).all()          # monotone
        assert out.min() >= 0 and out.max() <= (1 << 15)


def test_pim_partitioning_invariance_property():
    """Integer map-reduce results are identical for ANY core count."""
    for rng in _cases(30, 10):
        n = rng.randint(10, 300)
        x = rng.randint(-1000, 1000, n).astype(np.int32)

        def kern(xc, mask, _):
            return {"s": jnp.sum(xc * mask)}

        outs = set()
        for cores in rng.choice([1, 2, 4, 8, 16], size=3, replace=False):
            pim = PimSystem(PimConfig(n_cores=int(cores)))
            xs = pim.shard_rows(x)
            mask = pim.row_validity_mask(n).astype(jnp.int32)
            outs.add(int(pim.map_reduce(kern, (xs, mask), (0,))["s"]))
        assert len(outs) == 1


def test_kmeans_assign_labels_are_argmin_property():
    from repro.kernels.kmeans_assign.ops import assign_and_accumulate
    for rng in _cases(40, 10):
        n = int(rng.randint(8, 200))
        f = int(rng.choice([4, 8, 16]))
        k = int(rng.choice([2, 4, 8]))
        x = jnp.asarray(rng.randint(-2047, 2048, (n, f)), jnp.int16)
        c = jnp.asarray(rng.randint(-2047, 2048, (k, f)), jnp.int16)
        labels, sums, counts = assign_and_accumulate(
            x, c, use_pallas=True, block_n=64)
        d = ((np.asarray(x, np.int64)[:, None, :]
              - np.asarray(c, np.int64)[None]) ** 2).sum(-1)
        np.testing.assert_array_equal(np.asarray(labels), d.argmin(1))
        assert int(counts.sum()) == n


def test_attention_cache_invariance_property():
    """Decode-with-cache == teacher forcing for random small models."""
    from repro.configs.base import get_config
    from repro.models.api import Model
    for i, rng in enumerate(_cases(50, 4)):
        cfg = get_config("granite-3-8b").reduced(
            n_layers=int(rng.choice([1, 2])),
            d_model=int(rng.choice([64, 128])),
            vocab_size=int(rng.choice([64, 256])))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(i))
        S = int(rng.choice([8, 16]))
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, S)))
        _, cache = model.prefill(params, {"tokens": toks[:, :-1]},
                                 max_seq=S)
        dec, _ = model.decode_step(params, toks[:, -1:], cache)
        full = model.forward(params, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(dec[:, 0]),
                                   np.asarray(full[:, -1]),
                                   atol=2e-3, rtol=2e-3)


def test_moe_dispatch_equivalence_property():
    """gather and dense dispatch agree for random dropless specs."""
    import dataclasses
    from repro.models.moe import MoeSpec, init_moe, moe_apply
    for i, rng in enumerate(_cases(60, 8)):
        e = int(rng.choice([4, 8]))
        k = int(rng.choice([1, 2]))
        g = int(rng.choice([1, 2, 4]))
        spec_d = MoeSpec(d_model=32, n_experts=e, n_experts_real=e - 1,
                         top_k=k, d_ff=16, capacity_factor=float(4 * e),
                         dispatch="dense")
        spec_g = dataclasses.replace(spec_d, dispatch="gather", groups=g)
        p = init_moe(jax.random.PRNGKey(i), spec_d, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (2, 8, 32))
        od, _ = moe_apply(p, spec_d, x)
        og, _ = moe_apply(p, spec_g, x)
        np.testing.assert_allclose(np.asarray(od), np.asarray(og),
                                   atol=2e-5)


def test_checkpoint_roundtrip_property(tmp_path):
    """Arbitrary pytrees survive save/restore bit-exactly."""
    from repro.train import checkpoint as ckpt
    for i, rng in enumerate(_cases(70, 6)):
        tree = {
            "a": jnp.asarray(rng.normal(size=tuple(
                rng.randint(1, 8, size=2))), jnp.float32),
            "b": {"c": jnp.asarray(rng.randint(0, 100, 5), jnp.int32),
                  "d": jnp.asarray(rng.normal(size=3), jnp.bfloat16)},
        }
        d = str(tmp_path / f"case{i}")
        ckpt.save(d, 1, tree)
        back = ckpt.restore(d, 1, tree)
        for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(
                np.asarray(l1, np.float32), np.asarray(l2, np.float32))
