"""Distributed substrate: sharding rules, checkpoint round-trip,
fault-tolerance logic, grad compression, train loop."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.api import Model
from repro.optim.adam import AdamW, SGD
from repro.optim.grad_compression import compressed_bytes_saved
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (StragglerMonitor, plan_rescale,
                                         run_with_recovery)
from repro.train.loop import make_train_step
from repro.data.tokens import MarkovCorpus


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_spec_rules():
    from repro.distributed.sharding import spec_for_param
    from jax.sharding import PartitionSpec as P
    import jax.tree_util as jtu

    class L:
        def __init__(self, ndim):
            self.ndim = ndim
            self.shape = (128,) * ndim

    def path(*names):
        return tuple(jtu.DictKey(n) for n in names)

    assert spec_for_param(path("unit", "0", "attn", "wq"), L(3)) == \
        P(None, None, "model")
    assert spec_for_param(path("unit", "0", "attn", "wo"), L(3)) == \
        P(None, "model", None)
    assert spec_for_param(path("unit", "0", "moe", "w_gate"), L(4)) == \
        P(None, "model", None, None)
    assert spec_for_param(path("unit", "0", "mlstm", "w_gate"), L(3)) == \
        P(None, None, "model")
    assert spec_for_param(path("tok_emb"), L(2)) == P("model", None)
    assert spec_for_param(path("unit", "0", "norm1"), L(1)) == P()


def test_validate_divisibility_drops_bad_axes():
    from repro.distributed.sharding import validate_divisibility
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    # size-1 model axis divides everything
    assert validate_divisibility(P("model", None), (7, 3), mesh) == \
        P("model", None)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(gnorm) == pytest.approx(np.sqrt(3) * 100, rel=1e-4)


def test_bf16_params_get_f32_moments():
    opt = AdamW()
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# train loop (+ microbatching invariance)
# ---------------------------------------------------------------------------

def test_microbatch_grad_accum_matches_full_batch():
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    corpus = MarkovCorpus(cfg.vocab_size, seed=0)
    batch = jax.tree_util.tree_map(jnp.asarray, corpus.batch(8, 16))

    outs = {}
    for k in (1, 4):
        step = jax.jit(make_train_step(model, opt, microbatches=k))
        p2, _, m = step(params, opt.init(params), batch)
        outs[k] = (np.asarray(m["loss"]),
                   np.asarray(jax.tree_util.tree_leaves(p2)[0],
                              np.float32))
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=1e-4)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-2,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, state, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    # pruned to last 2
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2
    restored = ckpt.restore(str(tmp_path), 4, state)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    state = {"a": jnp.zeros((2, 3))}
    ckpt.save(str(tmp_path), 1, state)
    bad = {"a": jnp.zeros((3, 2))}
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, bad)


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    state = {"a": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 7, state)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(z_threshold=3.0)
    for _ in range(50):
        assert not m.observe(1.0 + np.random.RandomState(0).rand() * 1e-3)
    assert m.observe(10.0)      # 10x step time = straggler
    assert m.flagged == 1


def test_plan_rescale():
    assert plan_rescale(256, 16) == (16, 16)
    assert plan_rescale(240, 16) == (15, 16)     # one host lost
    assert plan_rescale(8, 16) is None           # fewer than one tp group
    assert plan_rescale(512, 16, pod_axis=True) == (2, 16, 16)


def test_run_with_recovery_restores_after_injected_fault(tmp_path):
    """Injected failure mid-training: state must roll back to the last
    checkpoint and training must still complete all steps."""
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 5 and calls["n"] == 6:     # fail once at step 5
            raise RuntimeError("injected node failure")
        return state + 1

    saved = {}

    def save_fn(state, step):
        saved[step] = state

    def restore_fn(step):
        return saved[step]

    final, stats = run_with_recovery(
        step_fn, save_fn, restore_fn, n_steps=10, ckpt_every=2, state=0)
    assert final == 10
    assert stats.failures == 1 and stats.restores == 1
    assert stats.steps_lost == 1  # failed at 5, last ckpt at 4


# ---------------------------------------------------------------------------
# gradient compression (multi-device; subprocess so device count is fresh)
# ---------------------------------------------------------------------------

def test_compressed_bytes_saved():
    f32, int8 = compressed_bytes_saved({"w": jnp.zeros((128, 128))})
    assert f32 == 4 * int8


@pytest.mark.slow
def test_dp_compressed_training_subprocess():
    """int8-compressed DP all-reduce trains within noise of the exact one
    (runs in a subprocess to force 8 host devices)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models.api import Model
from repro.optim.adam import AdamW
from repro.optim.grad_compression import init_error_buffers
from repro.train.loop import make_dp_train_step
from repro.data.tokens import MarkovCorpus

mesh = jax.make_mesh((8,), ("data",))
cfg = get_config("granite-3-8b").reduced()
model = Model(cfg)
corpus = MarkovCorpus(cfg.vocab_size, seed=0)
losses = {}
for compress in (False, True):
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    err = init_error_buffers(params)
    step = jax.jit(make_dp_train_step(model, opt, mesh,
                                      compress=compress))
    ls = []
    for i in range(8):
        batch = jax.tree_util.tree_map(jnp.asarray, corpus.batch(16, 16))
        with mesh:
            params, opt_state, err, m = step(params, opt_state, err, batch)
        ls.append(float(m["loss"]))
    losses[compress] = ls
print("exact", losses[False][-1], "compressed", losses[True][-1])
assert losses[True][-1] < losses[True][0], "compressed run must learn"
assert abs(losses[True][-1] - losses[False][-1]) < 0.35, losses
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_elastic_rescale_subprocess():
    """Train on 8 devices, checkpoint, 'lose' 4, restore onto a 4-device
    mesh, keep training — the elastic-rescale path end to end."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.models.api import Model
from repro.optim.adam import AdamW
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step
from repro.train.fault_tolerance import plan_rescale
from repro.distributed.sharding import param_shardings
from repro.data.tokens import MarkovCorpus

cfg = get_config("granite-3-8b").reduced()
model = Model(cfg)
opt = AdamW(lr=1e-3)
corpus = MarkovCorpus(cfg.vocab_size, seed=0)
step = jax.jit(make_train_step(model, opt))

devs = jax.devices()
mesh8 = Mesh(np.array(devs[:8]).reshape(4, 2), ("data", "model"))
params = model.init(jax.random.PRNGKey(0))
params = jax.device_put(params, param_shardings(mesh8, params))
opt_state = opt.init(params)
batch = jax.tree_util.tree_map(jnp.asarray, corpus.batch(8, 16))
params, opt_state, m0 = step(params, opt_state, batch)
d = tempfile.mkdtemp()
ckpt.save(d, 1, params)

# "lose" 4 devices -> plan a 2x2 mesh with tp kept at 2
shape = plan_rescale(4, 2)
assert shape == (2, 2), shape
mesh4 = Mesh(np.array(devs[:4]).reshape(2, 2), ("data", "model"))
shard4 = param_shardings(mesh4, params)
restored = ckpt.restore(d, 1, jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), shard4)
opt_state4 = opt.init(restored)
params4, _, m1 = step(restored, opt_state4, batch)
print("loss8", float(m0["loss"]), "loss4-after-rescale", float(m1["loss"]))
assert np.isfinite(float(m1["loss"]))
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_async_checkpointer(tmp_path):
    """Background writer must produce identical checkpoints and never
    leave partial state visible."""
    state = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    ck = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3):
        ck.save(s, jax.tree_util.tree_map(lambda v: v + s, state))
    ck.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored = ckpt.restore(str(tmp_path), 3, state)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]) + 3)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
