"""Elastic job runtime (DESIGN.md §11): chunk-boundary checkpoints,
preemption/resume, cross-System migration, supervised retry under
injected faults, allocator defragmentation, and crash-survivable
manifest queues."""
import json
import os

import numpy as np
import pytest

from repro import elastic
from repro.elastic import (FaultInjector, InjectedFault, injector_from_env,
                           job_fingerprint, migration_ok)
from repro.sched import PimScheduler, JobState, run_manifest
from repro.systems import (ChunkTick, HostConfig, HostSystem,
                           GpuModelConfig, ModeledGpuSystem, PimConfig,
                           PimSystem)
from repro.train import checkpoint as train_ckpt


def _regression(n=96, f=6, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.randn(n, f).astype(np.float32)
    y = (X @ rng.randn(f) + 0.1 * rng.randn(n)).astype(np.float32)
    return X, y


def _blobs(n=96, f=4, seed=3):
    rng = np.random.RandomState(seed)
    centers = rng.randn(4, f).astype(np.float32) * 4
    X = (centers[rng.randint(0, 4, n)]
         + rng.randn(n, f).astype(np.float32))
    return X.astype(np.float32), None


def _pim_sched(cores=8, rank=4, **kw):
    return PimScheduler(PimSystem(PimConfig(n_cores=cores)),
                        rank_size=rank, **kw)


def _reference(workload, data, **params):
    s = _pim_sched()
    h = s.submit(workload, data, **params)
    s.drain()
    assert h.state is JobState.DONE
    return h


# ---------------------------------------------------------------------------
# train/checkpoint.py keep_last pruning race (satellite regression test)
# ---------------------------------------------------------------------------

class TestPruneRace:
    def test_previously_latest_survives_one_save(self, tmp_path):
        """keep_last=1 must never delete the checkpoint a concurrent
        restore() could have selected via latest_step() before the new
        save published: prune only strictly older than the latest
        *durable* step."""
        d = str(tmp_path / "ck")
        train_ckpt.save(d, 1, {"w": np.ones(3)}, keep_last=1)
        train_ckpt.save(d, 2, {"w": np.ones(3) * 2}, keep_last=1)
        # step 1 was the durable latest when save(2) started -> kept
        assert train_ckpt.latest_step(d) == 2
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [1, 2]
        train_ckpt.save(d, 3, {"w": np.ones(3) * 3}, keep_last=1)
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [2, 3]          # 1 now strictly older -> pruned


# ---------------------------------------------------------------------------
# ChunkTick + trainer-level snapshots
# ---------------------------------------------------------------------------

class TestChunkTick:
    def test_is_an_int(self):
        t = ChunkTick(4, lambda: {"arrays": {}, "meta": {"iters": 4}})
        assert isinstance(t, int) and t == 4 and t.resumable
        assert t.snapshot()["meta"]["iters"] == 4

    def test_plain_tick_not_resumable(self):
        assert not ChunkTick(1).resumable


# ---------------------------------------------------------------------------
# Scheduler preempt/resume: bit-identity for every integer version
# ---------------------------------------------------------------------------

class TestPreemptResume:
    @pytest.mark.parametrize("workload,version,params", [
        ("linreg", "int32", {"n_iters": 24, "fuse_steps": 4}),
        ("linreg", "hyb", {"n_iters": 24, "fuse_steps": 1}),
        ("linreg", "int32", {"n_iters": 24, "fuse_steps": 1,
                             "minibatch": 32}),
        ("logreg", "int32", {"n_iters": 24, "fuse_steps": 4}),
    ])
    def test_gd_bit_identical(self, workload, version, params):
        X, y = _regression()
        if workload == "logreg":
            y = (y > np.median(y)).astype(np.float32)
        ref = _reference(workload, (X, y), version=version, **params)

        s = _pim_sched()
        h = s.submit(workload, (X, y), version=version, **params)
        s.step(); s.step(); s.step()
        h.preempt()
        s.step()
        assert h.state is JobState.PREEMPTED
        assert h.snapshot is not None and h.snapshot_kind == "pim"
        mid_iters = h.iters
        assert 0 < mid_iters < params["n_iters"]
        # resume on a FRESH scheduler (fresh lease, fresh System)
        s2 = _pim_sched()
        s2.resume(h, data=(X, y))
        s2.drain()
        assert h.state is JobState.DONE and h.iters == params["n_iters"]
        assert h.preemptions == 1
        np.testing.assert_array_equal(np.asarray(h.result.model.w),
                                      np.asarray(ref.result.model.w))
        np.testing.assert_array_equal(np.asarray(h.result.model.b),
                                      np.asarray(ref.result.model.b))

    @pytest.mark.parametrize("fuse", [1, 4])
    def test_kmeans_bit_identical_across_restarts(self, fuse):
        X, _ = _blobs()
        # tol=0 keeps Lloyd's running to max_iter, so the preempt always
        # lands mid-fit (well-separated blobs otherwise converge in 2-3)
        params = dict(n_clusters=4, max_iter=12, n_init=2, seed=1,
                      tol=0.0, fuse_steps=fuse)
        ref = _reference("kmeans", (X, None), version="int16", **params)

        s = _pim_sched()
        h = s.submit("kmeans", (X, None), version="int16", **params)
        for _ in range(3):
            s.step()
        h.preempt()
        s.step()
        assert h.state is JobState.PREEMPTED
        s2 = _pim_sched()
        s2.resume(h, data=(X, None))
        s2.drain()
        assert h.state is JobState.DONE
        rm, hm = ref.result.model, h.result.model
        np.testing.assert_array_equal(hm.centroids, rm.centroids)
        np.testing.assert_array_equal(hm.labels, rm.labels)
        assert hm.inertia == rm.inertia and hm.n_iters == rm.n_iters

    def test_non_resumable_workload_restarts(self):
        X, y = _regression()
        y = (y > np.median(y)).astype(np.int32)
        s = _pim_sched()
        h = s.submit("dtree", (X, y), max_depth=4)
        s.step(); s.step()
        h.preempt()
        s.step()
        assert h.state is JobState.PREEMPTED and h.snapshot is None
        s.resume(h)
        s.drain()
        assert h.state is JobState.DONE     # restarted from scratch


# ---------------------------------------------------------------------------
# Cross-System migration
# ---------------------------------------------------------------------------

class TestMigration:
    def test_matrix(self):
        assert migration_ok("pim", "host", "fp32")
        assert migration_ok("host", "gpu-model", "int32")
        assert migration_ok("pim", "pim", "int16")
        assert not migration_ok("pim", "host", "int32")
        assert not migration_ok("host", "pim", "int16")

    def _mixed(self):
        return PimScheduler({"pim": PimSystem(PimConfig(n_cores=8)),
                             "host": HostSystem(HostConfig(n_cores=4))},
                            rank_size=4)

    def test_fp32_pim_to_host_tolerance(self):
        X, y = _regression()
        s = self._mixed()
        h = s.submit("linreg", (X, y), version="fp32", n_iters=30,
                     target="pim")
        s.step(); s.step()
        h.preempt(); s.step()
        assert h.state is JobState.PREEMPTED
        s.resume(h, target="host")
        s.drain()
        assert h.state is JobState.DONE and h.target == "host"
        ref = PimScheduler(HostSystem(HostConfig(n_cores=4)))
        r = ref.submit("linreg", (X, y), version="fp32", n_iters=30)
        ref.drain()
        np.testing.assert_allclose(np.asarray(h.result.model.w),
                                   np.asarray(r.result.model.w),
                                   rtol=1e-4, atol=1e-5)

    def test_integer_migration_rejected_then_resumes_home(self):
        X, y = _regression()
        s = self._mixed()
        h = s.submit("linreg", (X, y), version="int32", n_iters=20,
                     target="pim")
        s.step()
        h.preempt(); s.step()
        with pytest.raises(ValueError, match="fixed-point"):
            s.resume(h, target="host")
        s.resume(h, target="pim")       # like-kind resume still works
        s.drain()
        assert h.state is JobState.DONE


# ---------------------------------------------------------------------------
# Priority preemption + defragmentation
# ---------------------------------------------------------------------------

class TestPreemptiveAdmission:
    def test_high_priority_evicts_and_everyone_finishes(self):
        X, y = _regression()
        s = _pim_sched(preemptive=True)
        low1 = s.submit("linreg", (X, y), version="int32", n_iters=30,
                        priority=0, name="low1")
        low2 = s.submit("linreg", (X, y), version="int32", n_iters=30,
                        priority=0, name="low2")
        s.step()
        assert low1.state is JobState.RUNNING
        assert low2.state is JobState.RUNNING
        hi = s.submit("linreg", (X, y), version="int32", n_iters=10,
                      priority=5, name="hi")
        s.step()
        assert hi.state is JobState.RUNNING
        assert low1.preemptions + low2.preemptions == 1
        s.drain()
        assert all(h.state is JobState.DONE for h in (low1, low2, hi))
        ref = _reference("linreg", (X, y), version="int32", n_iters=30)
        evicted = low2 if low2.preemptions else low1
        np.testing.assert_array_equal(np.asarray(evicted.result.model.w),
                                      np.asarray(ref.result.model.w))

    def test_non_preemptive_never_evicts(self):
        X, y = _regression()
        s = _pim_sched(preemptive=False)
        low = s.submit("linreg", (X, y), version="int32", n_iters=10,
                       n_cores=8)
        s.step()
        hi = s.submit("linreg", (X, y), version="int32", n_iters=10,
                      priority=5)
        s.step()
        assert hi.state is JobState.QUEUED and low.preemptions == 0
        s.drain()

    def test_defragment_coalesces_holes(self):
        X, y = _regression()
        s = _pim_sched(cores=16, rank=4)
        hs = [s.submit("linreg", (X, y), version="int32", n_iters=60,
                       name=f"j{i}") for i in range(4)]
        s.step()                       # leases [0,4) [4,8) [8,12) [12,16)
        hs[1].cancel(); hs[3].cancel()
        s.step()                       # holes at [4,8) and [12,16)
        assert s.fragmentation().external_fragmentation > 0
        moved = s.defragment()
        assert moved == 2
        s.step()                       # survivors re-admitted, packed
        assert s.fragmentation().external_fragmentation == 0.0
        s.drain()
        assert hs[0].state is JobState.DONE
        assert hs[2].state is JobState.DONE
        ref = _reference("linreg", (X, y), version="int32", n_iters=60)
        np.testing.assert_array_equal(np.asarray(hs[0].result.model.w),
                                      np.asarray(ref.result.model.w))
        np.testing.assert_array_equal(np.asarray(hs[2].result.model.w),
                                      np.asarray(ref.result.model.w))

    @pytest.mark.slow
    def test_churn(self):
        """Sustained submit/preempt/cancel/defragment churn: every job
        still terminates, no lease leaks, allocator ends empty."""
        X, y = _regression()
        s = _pim_sched(cores=16, rank=4, preemptive=True)
        handles = []
        for wave in range(6):
            for i in range(3):
                handles.append(s.submit(
                    "linreg", (X, y), version="int32", n_iters=20,
                    priority=wave % 3, name=f"w{wave}j{i}"))
            for _ in range(4):
                s.step()
            if wave % 2:
                for h in handles:
                    if h.state is JobState.RUNNING:
                        h.preempt()
                        break
                s.step()
                for h in handles:
                    if h.state is JobState.PREEMPTED:
                        s.resume(h)
            s.defragment()
        s.drain()
        assert all(h.state in (JobState.DONE, JobState.CANCELLED)
                   for h in handles)
        frag = s.fragmentation()
        assert frag.used_cores == 0


# ---------------------------------------------------------------------------
# Fault injection + supervised retry
# ---------------------------------------------------------------------------

class TestFaultInjection:
    def test_parse(self):
        inj = FaultInjector.parse("job*:3, other:5:2")
        assert len(inj.plans) == 2
        assert inj("jobA", 3) is True          # fires once
        assert inj("jobA", 3) is False         # count exhausted
        assert inj("other", 5) and inj("other", 5) and not inj("other", 5)
        assert inj("unrelated", 3) is False

    def test_env(self, monkeypatch):
        monkeypatch.setenv(elastic.ENV_VAR, "x:1")
        inj = injector_from_env()
        assert inj is not None and inj("x", 1)
        monkeypatch.delenv(elastic.ENV_VAR)
        assert injector_from_env() is None

    def test_recovery_within_budget_bit_identical(self):
        X, y = _regression()
        ref = _reference("linreg", (X, y), version="int32", n_iters=20,
                         fuse_steps=2)
        inj = FaultInjector.parse("faulty:3")
        s = _pim_sched(fault_injector=inj)
        h = s.submit("linreg", (X, y), version="int32", n_iters=20,
                     fuse_steps=2, retry_budget=2, name="faulty")
        s.drain()
        assert h.state is JobState.DONE
        assert h.recoveries == 1               # the fault is on record
        assert isinstance(h.error, InjectedFault)
        np.testing.assert_array_equal(np.asarray(h.result.model.w),
                                      np.asarray(ref.result.model.w))
        assert s.stats()["recoveries"] == 1

    def test_budget_exhaustion_fails(self):
        X, y = _regression()
        inj = FaultInjector()
        inj.plan("dies", 2, count=10)
        s = _pim_sched(fault_injector=inj)
        h = s.submit("linreg", (X, y), version="int32", n_iters=20,
                     retry_budget=1, name="dies")
        s.drain()
        assert h.state is JobState.FAILED
        assert h.recoveries == 1
        assert isinstance(h.error, InjectedFault)

    def test_zero_budget_fails_immediately(self):
        X, y = _regression()
        s = _pim_sched(fault_injector=FaultInjector.parse("j:1"))
        h = s.submit("linreg", (X, y), version="int32", n_iters=10,
                     name="j")
        s.drain()
        assert h.state is JobState.FAILED and h.recoveries == 0


# ---------------------------------------------------------------------------
# Satellites: straggler stats + per-job modeled-GPU attribution
# ---------------------------------------------------------------------------

class TestObservability:
    def test_straggler_stats_exposed(self):
        X, y = _regression()
        s = _pim_sched()
        s.submit("linreg", (X, y), version="int32", n_iters=10)
        s.drain()
        stats = s.stats()
        assert "straggler_flags" in stats
        assert stats["straggler_flags"] >= 0

    def test_gpu_slice_attribution(self):
        X, y = _regression()
        s = PimScheduler(ModeledGpuSystem(GpuModelConfig(n_cores=8)),
                         rank_size=4)
        h1 = s.submit("linreg", (X, y), version="fp32", n_iters=16,
                      fuse_steps=4)
        h2 = s.submit("kmeans", (X, None), version="fp32", n_clusters=4,
                      max_iter=16, fuse_steps=4)
        s.drain()
        assert h1.gpu is not None and h2.gpu is not None
        assert h1.gpu.modeled_seconds > 0 and h2.gpu.modeled_seconds > 0
        total = s.system.gpu
        assert h1.gpu.launches + h2.gpu.launches <= total.launches
        assert (h1.gpu.modeled_seconds + h2.gpu.modeled_seconds
                <= total.modeled_seconds + 1e-12)


# ---------------------------------------------------------------------------
# Durable elastic checkpoints + crash-survivable queues
# ---------------------------------------------------------------------------

class TestDurability:
    def test_snapshot_disk_roundtrip(self, tmp_path):
        X, y = _regression()
        s = _pim_sched(checkpoint_dir=str(tmp_path), checkpoint_every=2)
        h = s.submit("linreg", (X, y), version="int32", n_iters=20,
                     fuse_steps=2, minibatch=32, name="rt")
        for _ in range(4):
            s.step()
        d = elastic.job_dir(str(tmp_path), "rt")
        assert elastic.has_checkpoint(d)
        snap, env = elastic.load_snapshot(d)
        assert env["workload"] == "linreg" and env["version"] == "int32"
        assert env["fingerprint"] == h.fingerprint
        assert env["system_kind"] == "pim"
        assert "rng_mt_keys" in snap["arrays"]      # exact stream resume
        assert snap["meta"]["iters"] == env["iters"] > 0

    def test_fingerprint_mismatch_refused(self, tmp_path):
        X, y = _regression()
        s = _pim_sched(checkpoint_dir=str(tmp_path))
        h = s.submit("linreg", (X, y), version="int32", n_iters=12,
                     name="fp")
        for _ in range(3):
            s.step()
        snap, env = elastic.load_snapshot(
            elastic.job_dir(str(tmp_path), "fp"))
        X2 = X + 1.0                               # different dataset
        s2 = _pim_sched(checkpoint_dir=str(tmp_path))
        h2 = s2.submit("linreg", (X2, y), version="int32", n_iters=12,
                       name="fp")
        with pytest.raises(ValueError, match="fingerprint"):
            s2.attach_resume_state(h2, snap, env)

    def test_fingerprint_format(self):
        X, y = _regression()
        fp = job_fingerprint("linreg", "int32", {"n_iters": 5}, X, y)
        a, b = fp.split("-")
        assert len(a) == 32 and len(b) == 32

    def test_killed_queue_resume_roundtrip(self, tmp_path):
        """The acceptance loop: run part of a manifest, abandon it,
        re-run with resume=True — the queue completes, finished work is
        not redone, unfinished work continues from its snapshot and
        stays bit-identical to an uninterrupted run."""
        manifest = {
            "system": {"cores": 16, "rank_size": 4},
            "datasets": {"lin": {"kind": "linear", "samples": 256,
                                 "features": 8, "seed": 0}},
            "jobs": [
                {"workload": "linreg", "dataset": "lin", "cores": 4,
                 "name": "quick", "version": "int32",
                 "params": {"n_iters": 6, "fuse_steps": 2}},
                {"workload": "linreg", "dataset": "lin", "cores": 4,
                 "name": "long", "version": "int32",
                 "params": {"n_iters": 60, "fuse_steps": 2}},
            ],
        }
        ck = str(tmp_path / "ck")
        sched, handles = run_manifest(manifest, drain=False,
                                      checkpoint_dir=ck)
        for _ in range(6):
            sched.step()
        by_name = {h.name: h for h in handles}
        assert by_name["quick"].state is JobState.DONE
        assert by_name["long"].state is JobState.RUNNING
        del sched                               # the "kill"

        q = json.load(open(os.path.join(ck, "queue.json")))
        assert {r["name"]: r["state"] for r in q["jobs"]} == {
            "quick": "done", "long": "running"}

        sched2, handles2 = run_manifest(manifest, checkpoint_dir=ck,
                                        resume=True)
        by_name2 = {h.name: h for h in handles2}
        assert by_name2["quick"].state is JobState.DONE
        assert by_name2["quick"].restored        # not re-run
        assert by_name2["quick"].steps == by_name["quick"].steps
        long2 = by_name2["long"]
        assert long2.state is JobState.DONE and not long2.restored
        assert long2.iters == 60

        ref_sched, ref_handles = run_manifest(manifest)
        ref = {h.name: h for h in ref_handles}["long"]
        np.testing.assert_array_equal(np.asarray(long2.result.model.w),
                                      np.asarray(ref.result.model.w))
