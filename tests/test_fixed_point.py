import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fixed_point import (_shift_round, from_fixed, fx_dot,
                                    fx_dot_hybrid, fx_mul, fx_recip,
                                    to_fixed)


def test_to_from_fixed_roundtrip():
    x = np.linspace(-4, 4, 101).astype(np.float32)
    q = to_fixed(x, 10)
    back = np.asarray(from_fixed(q, 10))
    assert np.abs(back - x).max() <= 2 ** -10


def test_to_fixed_saturates():
    q = to_fixed(np.array([300.0]), 7, dtype=jnp.int8)
    assert int(q[0]) == 127


def test_shift_round_rounds_to_nearest():
    # floor-shift of -1 >> 1 would give -1; round gives 0 or -1 consistently
    x = jnp.asarray([3, 5, -3, -5], jnp.int32)
    out = np.asarray(_shift_round(x, 1))
    assert list(out) == [2, 3, -1, -2]  # round-half-up behaviour


@pytest.mark.parametrize("frac", [8, 10, 12])
def test_fx_mul_matches_float(frac):
    rng = np.random.RandomState(0)
    a = rng.uniform(-4, 4, 256).astype(np.float32)
    b = rng.uniform(-4, 4, 256).astype(np.float32)
    out = from_fixed(fx_mul(to_fixed(a, frac), to_fixed(b, frac), frac), frac)
    assert np.abs(np.asarray(out) - a * b).max() < 40 * 2.0 ** -frac


def test_fx_dot_matches_float():
    rng = np.random.RandomState(1)
    X = rng.uniform(0, 1, (32, 16)).astype(np.float32)
    w = rng.uniform(-1, 1, 16).astype(np.float32)
    out = from_fixed(fx_dot(to_fixed(X, 10), to_fixed(w, 10), 10), 10)
    assert np.abs(np.asarray(out) - X @ w).max() < 16 * 2.0 ** -10 * 4


def test_fx_dot_hybrid_close_and_saturating():
    rng = np.random.RandomState(2)
    X = rng.uniform(0, 1, (8, 16)).astype(np.float32)
    w = rng.uniform(-1, 1, 16).astype(np.float32)
    out = from_fixed(
        fx_dot_hybrid(to_fixed(X, 7, dtype=jnp.int8),
                      to_fixed(w, 8, dtype=jnp.int16), 7, 8, 10), 10)
    assert np.abs(np.asarray(out) - X @ w).max() < 0.1
    # saturation: huge weights would overflow int16 accumulation
    w_big = np.full(16, 60.0, np.float32)
    out_sat = fx_dot_hybrid(to_fixed(X, 7, dtype=jnp.int8),
                            to_fixed(w_big, 8, dtype=jnp.int16), 7, 8, 10)
    assert int(np.max(np.asarray(out_sat))) <= 2 ** 15 - 1


def test_fx_recip():
    rng = np.random.RandomState(3)
    d = rng.uniform(0.5, 8.0, 64).astype(np.float32)
    r = from_fixed(fx_recip(to_fixed(d, 10), 10), 10)
    assert np.abs(np.asarray(r) - 1.0 / d).max() < 0.01
