"""PIM execution model invariants (core/pim.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pim import (DpuCostModel, PimConfig, PimSystem, ReduceVia)


def _sum_kernel(xc, w):
    return {"s": jnp.sum(xc * w)}


def test_shard_rows_pads_to_equal_shards():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(10, dtype=np.float32)
    xs = pim.shard_rows(x)
    assert xs.shape == (4, 3)
    mask = np.asarray(pim.row_validity_mask(10))
    assert mask.sum() == 10
    assert mask.shape == (4, 3)


def test_map_reduce_sums_across_cores():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(12, dtype=np.float32)
    xs = pim.shard_rows(x)
    out = pim.map_reduce(_sum_kernel, (xs,), (jnp.float32(2.0),))
    assert float(out["s"]) == 2.0 * x.sum()


def test_host_reduce_matches_fabric():
    x = np.random.RandomState(0).uniform(-1, 1, 64).astype(np.float32)
    outs = {}
    for mode in (ReduceVia.FABRIC, ReduceVia.HOST):
        pim = PimSystem(PimConfig(n_cores=8, reduce=mode))
        xs = pim.shard_rows(x)
        outs[mode] = float(pim.map_reduce(
            _sum_kernel, (xs,), (jnp.float32(1.0),))["s"])
    # fabric sums the per-core partials in f32 on device; host promotes to
    # f64.  The 64 uniform(-1,1) values cancel to ~0.097, so the f32 path
    # carries ~1e-6 absolute rounding noise — compare absolutely, not at
    # f64-tight relative precision.
    assert outs[ReduceVia.FABRIC] == pytest.approx(outs[ReduceVia.HOST],
                                                   abs=1e-5)


def test_result_independent_of_core_count_int():
    """Partitioning must not change integer results (paper determinism)."""
    x = np.random.RandomState(1).randint(-100, 100, 256).astype(np.int32)

    def kern(xc, _):
        return {"s": jnp.sum(xc)}

    res = []
    for cores in (1, 4, 16, 64):
        pim = PimSystem(PimConfig(n_cores=cores))
        xs = pim.shard_rows(x)
        res.append(int(pim.map_reduce(kern, (xs,), (0,))["s"]))
    assert len(set(res)) == 1


def test_transfer_stats_track_bytes():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.zeros(16, np.float32)
    pim.shard_rows(x)
    assert pim.stats.cpu_to_pim == 16 * 4
    pim.broadcast((jnp.zeros(3, jnp.float32),))
    assert pim.stats.cpu_to_pim == 16 * 4 + 4 * 3 * 4


def test_map_elementwise_keeps_core_axis():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(8, dtype=np.float32)
    xs = pim.shard_rows(x)
    out = pim.map_elementwise(lambda xc, c: xc + c, (xs,),
                              (jnp.float32(10.0),))
    assert out.shape == (4, 2)
    assert np.allclose(np.asarray(out).ravel(), x + 10)


def test_map_reduce_custom_minmax():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.random.RandomState(2).uniform(-5, 5, 32).astype(np.float32)
    xs = pim.shard_rows(x, pad_value=0)

    def kern(xc, _):
        return {"min": jnp.min(xc), "max": jnp.max(xc)}

    out = pim.map_reduce_custom(kern, (xs,), (0,),
                                reduce={"min": "min", "max": "max"})
    assert float(out["min"]) == pytest.approx(x.min())
    assert float(out["max"]) == pytest.approx(x.max())


# ---------------------------------------------------------------------------
# DPU cost model: reproduces the paper's measured speedup ratios (§5.2).
# ---------------------------------------------------------------------------

def test_cost_model_pipeline_saturates_at_11_threads():
    m = DpuCostModel()
    t = [m.kernel_seconds(1e6, 0, n) for n in range(1, 25)]
    # monotone non-increasing, flat from 11 on (Fig. 8-10 shape)
    assert all(a >= b - 1e-12 for a, b in zip(t, t[1:]))
    assert t[10] == pytest.approx(t[23])
    assert t[0] / t[10] == pytest.approx(11.0, rel=1e-6)


def test_cost_model_version_ratios_match_paper():
    """Calibration check: modeled ratios within tolerance of paper's
    measured speedups (§5.2.1-§5.2.2)."""
    m = DpuCostModel()

    def sec(w, v):
        return m.workload_seconds(w, v, n_samples=2048, n_features=16,
                                  n_cores=1, n_threads=16)

    fp32_over_int32 = sec("lin", "fp32") / sec("lin", "int32")
    assert 7.0 < fp32_over_int32 < 13.0          # "order of magnitude"
    hyb_gain = sec("lin", "int32") / sec("lin", "hyb")
    assert 1.2 < hyb_gain < 1.7                   # paper: +41%
    bui_gain = sec("lin", "hyb") / sec("lin", "bui")
    assert 1.1 < bui_gain < 1.45                  # paper: +25%
    lut_gain = sec("log", "int32") / sec("log", "int32_lut_wram")
    assert lut_gain > 1.5                         # LUT >> Taylor
    mram_penalty = (sec("log", "int32_lut_mram")
                    / sec("log", "int32_lut_wram"))
    assert 1.0 <= mram_penalty < 1.2              # paper: ~3%


def test_cost_model_strong_scaling_linear():
    """PIM kernel time scales ~linearly with cores (paper Fig. 12)."""
    m = DpuCostModel()
    t256 = m.workload_seconds("dtr", "fp32", 153_600_000, 16, 256, 16)
    t2048 = m.workload_seconds("dtr", "fp32", 153_600_000, 16, 2048, 16)
    assert t256 / t2048 == pytest.approx(8.0, rel=0.05)
