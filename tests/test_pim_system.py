"""PIM execution model invariants (core/pim.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pim import (HierarchicalReduce, PimConfig,
                            PimSystem, ReduceVia, TransferStats)
from repro.systems.topology import HierarchicalCostModel


def _sum_kernel(xc, w):
    return {"s": jnp.sum(xc * w)}


def test_shard_rows_pads_to_equal_shards():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(10, dtype=np.float32)
    xs = pim.shard_rows(x)
    assert xs.shape == (4, 3)
    mask = np.asarray(pim.row_validity_mask(10))
    assert mask.sum() == 10
    assert mask.shape == (4, 3)


def test_map_reduce_sums_across_cores():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(12, dtype=np.float32)
    xs = pim.shard_rows(x)
    out = pim.map_reduce(_sum_kernel, (xs,), (jnp.float32(2.0),))
    assert float(out["s"]) == 2.0 * x.sum()


def test_host_reduce_matches_fabric():
    x = np.random.RandomState(0).uniform(-1, 1, 64).astype(np.float32)
    outs = {}
    for mode in (ReduceVia.FABRIC, ReduceVia.HOST):
        pim = PimSystem(PimConfig(n_cores=8, reduce=mode))
        xs = pim.shard_rows(x)
        outs[mode] = float(pim.map_reduce(
            _sum_kernel, (xs,), (jnp.float32(1.0),))["s"])
    # fabric sums the per-core partials in f32 on device; host promotes to
    # f64.  The 64 uniform(-1,1) values cancel to ~0.097, so the f32 path
    # carries ~1e-6 absolute rounding noise — compare absolutely, not at
    # f64-tight relative precision.
    assert outs[ReduceVia.FABRIC] == pytest.approx(outs[ReduceVia.HOST],
                                                   abs=1e-5)


def test_result_independent_of_core_count_int():
    """Partitioning must not change integer results (paper determinism)."""
    x = np.random.RandomState(1).randint(-100, 100, 256).astype(np.int32)

    def kern(xc, _):
        return {"s": jnp.sum(xc)}

    res = []
    for cores in (1, 4, 16, 64):
        pim = PimSystem(PimConfig(n_cores=cores))
        xs = pim.shard_rows(x)
        res.append(int(pim.map_reduce(kern, (xs,), (0,))["s"]))
    assert len(set(res)) == 1


def test_transfer_stats_track_bytes():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.zeros(16, np.float32)
    pim.shard_rows(x)
    assert pim.stats.cpu_to_pim == 16 * 4
    pim.broadcast((jnp.zeros(3, jnp.float32),))
    assert pim.stats.cpu_to_pim == 16 * 4 + 4 * 3 * 4


def test_map_elementwise_keeps_core_axis():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.arange(8, dtype=np.float32)
    xs = pim.shard_rows(x)
    out = pim.map_elementwise(lambda xc, c: xc + c, (xs,),
                              (jnp.float32(10.0),))
    assert out.shape == (4, 2)
    assert np.allclose(np.asarray(out).ravel(), x + 10)


def test_map_reduce_custom_minmax():
    pim = PimSystem(PimConfig(n_cores=4))
    x = np.random.RandomState(2).uniform(-5, 5, 32).astype(np.float32)
    xs = pim.shard_rows(x, pad_value=0)

    def kern(xc, _):
        return {"min": jnp.min(xc), "max": jnp.max(xc)}

    out = pim.map_reduce_custom(kern, (xs,), (0,),
                                reduce={"min": "min", "max": "max"})
    assert float(out["min"]) == pytest.approx(x.min())
    assert float(out["max"]) == pytest.approx(x.max())


# ---------------------------------------------------------------------------
# HierarchicalReduce edge cases: group sizes that do not divide (or
# exceed) the core count must fall back to the flat host schedule with
# correct byte accounting.
# ---------------------------------------------------------------------------

def _int_sum_kernel(xc, _):
    return {"s": jnp.sum(xc)}


@pytest.mark.parametrize("group_size", [3, 5, 16, 1])
def test_hierarchical_awkward_group_size_matches_fabric(group_size):
    """group_size not dividing n_cores=8 (3, 5), larger than it (16),
    and degenerate (1) all reduce to the exact FabricReduce result."""
    x = np.random.RandomState(0).randint(-1000, 1000, 123).astype(np.int32)

    fab = PimSystem(PimConfig(n_cores=8))
    expect = int(fab.map_reduce(_int_sum_kernel, (fab.shard_rows(x),),
                                (0,), strategy="fabric")["s"])

    pim = PimSystem(PimConfig(n_cores=8))
    xs = pim.shard_rows(x)
    out = pim.map_reduce(_int_sum_kernel, (xs,), (0,),
                         strategy=HierarchicalReduce(group_size))
    assert int(out["s"]) == expect


def test_hierarchical_flat_fallback_byte_counts():
    """An awkward group size means NO rank-level reduction happened: the
    PIM->CPU bytes must equal the full per-core partial set (as HostReduce
    counts) and no inter-core-via-host bytes may be recorded."""
    x = np.arange(64, dtype=np.int32)
    pim = PimSystem(PimConfig(n_cores=8))
    xs = pim.shard_rows(x)
    before = pim.stats.snapshot()
    pim.map_reduce(_int_sum_kernel, (xs,), (0,),
                   strategy=HierarchicalReduce(3))
    d = pim.stats.delta(before)
    assert d.pim_to_cpu == 8 * 4          # all 8 int32 partials ship flat
    assert d.inter_core_via_host == 0     # no rank leaders existed


def test_hierarchical_dividing_group_size_byte_counts():
    """The intended two-level schedule: 8 cores in ranks of 4 ship 2 rank
    partials to the host and record the rank->host leg separately."""
    x = np.arange(64, dtype=np.int32)
    pim = PimSystem(PimConfig(n_cores=8))
    xs = pim.shard_rows(x)
    before = pim.stats.snapshot()
    out = pim.map_reduce(_int_sum_kernel, (xs,), (0,),
                         strategy=HierarchicalReduce(4))
    d = pim.stats.delta(before)
    assert int(out["s"]) == int(x.sum())
    assert d.pim_to_cpu == 2 * 4          # two int32 rank partials
    assert d.inter_core_via_host == 2 * 4
    # 1/group_size of the flat-host bytes, the hierarchy's saving
    assert d.pim_to_cpu == (8 * 4) // 4


def test_hierarchical_group_equal_to_cores():
    """group_size == n_cores degenerates to one rank: a single partial
    crosses the host link."""
    x = np.arange(48, dtype=np.float32)
    pim = PimSystem(PimConfig(n_cores=8))
    xs = pim.shard_rows(x)
    before = pim.stats.snapshot()
    out = pim.map_reduce(_int_sum_kernel, (xs,), (0,),
                         strategy=HierarchicalReduce(8))
    d = pim.stats.delta(before)
    assert float(out["s"]) == pytest.approx(x.sum())
    assert d.pim_to_cpu == 1 * 4


def test_transfer_stats_snapshot_delta():
    s = TransferStats()
    s.cpu_to_pim = 100
    s.kernel_launches = 3
    snap = s.snapshot()
    s.cpu_to_pim += 50
    s.pim_to_cpu += 7
    s.kernel_launches += 2
    d = s.delta(snap)
    assert (d.cpu_to_pim, d.pim_to_cpu, d.kernel_launches) == (50, 7, 2)
    assert snap.cpu_to_pim == 100         # snapshot is immutable-by-copy
    s.reset()
    assert s.cpu_to_pim == 0 and s.kernel_launches == 0


# ---------------------------------------------------------------------------
# DPU cost model: reproduces the paper's measured speedup ratios (§5.2).
# ---------------------------------------------------------------------------

def test_cost_model_pipeline_saturates_at_11_threads():
    m = HierarchicalCostModel.for_cores(1)
    t = [m.kernel_seconds(1e6, 0, n) for n in range(1, 25)]
    # monotone non-increasing, flat from 11 on (Fig. 8-10 shape)
    assert all(a >= b - 1e-12 for a, b in zip(t, t[1:]))
    assert t[10] == pytest.approx(t[23])
    assert t[0] / t[10] == pytest.approx(11.0, rel=1e-6)


def test_cost_model_version_ratios_match_paper():
    """Calibration check: modeled ratios within tolerance of paper's
    measured speedups (§5.2.1-§5.2.2)."""
    m = HierarchicalCostModel.for_cores(1)

    def sec(w, v):
        return m.workload_seconds(w, v, n_samples=2048, n_features=16,
                                  n_cores=1, n_threads=16)

    fp32_over_int32 = sec("lin", "fp32") / sec("lin", "int32")
    assert 7.0 < fp32_over_int32 < 13.0          # "order of magnitude"
    hyb_gain = sec("lin", "int32") / sec("lin", "hyb")
    assert 1.2 < hyb_gain < 1.7                   # paper: +41%
    bui_gain = sec("lin", "hyb") / sec("lin", "bui")
    assert 1.1 < bui_gain < 1.45                  # paper: +25%
    lut_gain = sec("log", "int32") / sec("log", "int32_lut_wram")
    assert lut_gain > 1.5                         # LUT >> Taylor
    mram_penalty = (sec("log", "int32_lut_mram")
                    / sec("log", "int32_lut_wram"))
    assert 1.0 <= mram_penalty < 1.2              # paper: ~3%


def test_cost_model_strong_scaling_linear():
    """PIM kernel time scales ~linearly with cores (paper Fig. 12)."""
    m = HierarchicalCostModel.for_cores(1)
    t256 = m.workload_seconds("dtr", "fp32", 153_600_000, 16, 256, 16)
    t2048 = m.workload_seconds("dtr", "fp32", 153_600_000, 16, 2048, 16)
    assert t256 / t2048 == pytest.approx(8.0, rel=0.05)
