"""Loop-corrected HLO analyzer (the roofline's measurement instrument)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (corrected_totals,
                                       normalize_cost_analysis, parse_hlo)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_single_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    hlo = _compile(lambda x: x @ x, a)
    out = corrected_totals(hlo)
    assert out["flops"] == 2 * 128 ** 3


def test_scan_flops_multiplied_by_trips():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        return jax.lax.scan(lambda h, _: (h @ h, None), x, None,
                            length=12)[0]

    out = corrected_totals(_compile(f, a))
    assert out["flops"] == 12 * 2 * 64 ** 3


def test_nested_scan_flops():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def outer(h, _):
            h2 = jax.lax.scan(lambda g, _: (g @ g, None), h, None,
                              length=5)[0]
            return h2, None
        return jax.lax.scan(outer, x, None, length=3)[0]

    out = corrected_totals(_compile(f, a))
    assert out["flops"] == 15 * 2 * 32 ** 3


def test_cost_analysis_undercount_documented():
    """The reason this module exists: XLA counts while bodies once."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        return jax.lax.scan(lambda h, _: (h @ h, None), x, None,
                            length=8)[0]

    compiled = jax.jit(f).lower(a).compile()
    # cost_analysis() is a list of dicts on some jax versions and a
    # plain dict on others; the normalizer hides the drift
    raw = normalize_cost_analysis(compiled.cost_analysis())["flops"]
    corrected = corrected_totals(compiled.as_text())["flops"]
    assert corrected == pytest.approx(8 * raw, rel=0.01)


def test_normalize_cost_analysis_shapes():
    """The helper accepts every historical return shape."""
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost_analysis(({"flops": 2.0},)) == {"flops": 2.0}
    assert normalize_cost_analysis([]) == {}


def test_parse_hlo_finds_entry():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    comps = parse_hlo(_compile(lambda x: x + 1, a))
    assert "__entry__" in comps
