"""The legacy deprecation surface: ``core/estimators.py`` class shims
and the ``train(...)`` wrappers emit exactly one DeprecationWarning per
call and return results identical to the ``make_estimator``/``fit``
paths they shim.
"""
import warnings

import numpy as np
import pytest

from repro.api import PimConfig, PimSystem, make_estimator
from repro.core import dtree, kmeans, linreg, logreg
from repro.core.estimators import (PimDecisionTreeClassifier, PimKMeans,
                                   PimLinearRegression,
                                   PimLogisticRegression)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def _deprecations(fn):
    """Run fn capturing warnings; return (result, deprecation list)."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        result = fn()
    return result, [w for w in rec
                    if issubclass(w.category, DeprecationWarning)]


def _pim(n_cores=8):
    return PimSystem(PimConfig(n_cores=n_cores))


# ---------------------------------------------------------------------------
# train(...) wrappers: one warning, identical results to fit(put(...)).
# ---------------------------------------------------------------------------

def test_linreg_train_warns_once_and_matches_fit():
    X, y, _ = make_linear_dataset(256, 8, seed=0)
    cfg = linreg.GdConfig(version="int32", n_iters=20)
    r_legacy, deps = _deprecations(lambda: linreg.train(X, y, _pim(), cfg))
    assert len(deps) == 1
    r_new = linreg.fit(_pim().put(X, y), cfg)
    assert np.array_equal(r_legacy.w, r_new.w)
    assert r_legacy.b == r_new.b


def test_logreg_train_warns_once_and_matches_fit():
    X, y, _ = make_linear_dataset(256, 8, seed=1)
    cfg = logreg.LogRegConfig(version="int32_lut_wram", n_iters=15)
    r_legacy, deps = _deprecations(lambda: logreg.train(X, y, _pim(), cfg))
    assert len(deps) == 1
    r_new = logreg.fit(_pim().put(X, y), cfg)
    assert np.array_equal(r_legacy.w, r_new.w)
    assert r_legacy.b == r_new.b


def test_kmeans_train_warns_once_and_matches_fit():
    X, _, _ = make_blobs(256, 4, centers=4, seed=2)
    cfg = kmeans.KMeansConfig(k=4, max_iters=10)
    r_legacy, deps = _deprecations(lambda: kmeans.train(X, _pim(), cfg))
    assert len(deps) == 1
    r_new = kmeans.fit(_pim().put(X), cfg)
    assert np.array_equal(r_legacy.centroids, r_new.centroids)
    assert np.array_equal(r_legacy.labels, r_new.labels)
    assert r_legacy.inertia == r_new.inertia


def test_dtree_train_warns_once_and_matches_fit():
    X, y = make_classification(256, 8, seed=3, class_sep=1.5)
    cfg = dtree.TreeConfig(max_depth=2, seed=0)
    t_legacy, deps = _deprecations(lambda: dtree.train(X, y, _pim(), cfg))
    assert len(deps) == 1
    t_new = dtree.fit(_pim().put(X, y), cfg)
    assert t_legacy.n_nodes == t_new.n_nodes
    assert np.array_equal(t_legacy.predict(X), t_new.predict(X))


# ---------------------------------------------------------------------------
# Legacy estimator classes: one warning at construction, behaviour
# identical to the make_estimator facade.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("legacy_cls,name,params", [
    (PimLinearRegression, "linreg",
     dict(version="int32", n_iters=20)),
    (PimLogisticRegression, "logreg",
     dict(version="int32_lut_wram", n_iters=15)),
    (PimDecisionTreeClassifier, "dtree", dict(max_depth=2, seed=0)),
    (PimKMeans, "kmeans", dict(n_clusters=4, max_iter=10)),
])
def test_legacy_class_warns_once_and_matches_make_estimator(
        legacy_cls, name, params):
    if name == "kmeans":
        X, _, _ = make_blobs(256, 4, centers=4, seed=4)
        y = None
    elif name == "dtree":
        X, y = make_classification(256, 8, seed=5, class_sep=1.5)
    else:
        X, y, _ = make_linear_dataset(256, 8, seed=6)

    legacy, deps = _deprecations(lambda: legacy_cls(**params))
    assert len(deps) == 1
    assert "make_estimator" in str(deps[0].message)

    # fitting through the shim must NOT warn again (the shim is the
    # constructor; everything else is the facade)
    _, deps_fit = _deprecations(lambda: legacy.fit(X, y))
    assert len(deps_fit) == 0

    modern = make_estimator(name, **params).fit(X, y)
    pred_l, pred_m = legacy.predict(X), modern.predict(X)
    assert np.array_equal(pred_l, pred_m)
    if name in ("linreg", "logreg"):
        assert np.array_equal(legacy.coef_, modern.coef_)
        assert legacy.intercept_ == modern.intercept_
    elif name == "kmeans":
        assert np.array_equal(legacy.cluster_centers_,
                              modern.cluster_centers_)
    else:
        assert legacy.n_nodes_ == modern.n_nodes_


def test_sklearn_clone_round_trip_still_works():
    """cls(**est.get_params()) must reconstruct despite the warning."""
    est, deps = _deprecations(
        lambda: PimLinearRegression(version="hyb", n_iters=10))
    clone, deps2 = _deprecations(
        lambda: PimLinearRegression(**est.get_params()))
    assert len(deps) == len(deps2) == 1
    assert clone.get_params() == est.get_params()
