"""The backend-portable System protocol (repro/systems; DESIGN.md §10).

Covers cross-system parity — fp32 fits on HostSystem match the
PimSystem fabric path within float tolerance; the integer PIM versions
stay bit-identical through the old import path after the move;
ModeledGpuSystem returns HostSystem numerics EXACTLY while reporting
A100-roofline time/energy — plus per-system TransferStats semantics,
step fusion on host targets, the mixed PIM+host scheduler queue with
attributable per-job stats, the compare driver, and the legacy
``pim=``-only call paths (one DeprecationWarning, identical results —
pattern of tests/test_deprecation.py).
"""
import json
import warnings

import numpy as np
import pytest

from repro.api import (PimConfig, PimSystem, get_workload,
                       make_estimator, make_system)
from repro.core import dtree, kmeans, linreg, logreg
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)
from repro.sched import JobState, PimScheduler
from repro.systems import (HostSystem, ModeledGpuSystem, System,
                           TransferStats)

N, F, CORES = 256, 6, 8


@pytest.fixture(scope="module")
def lin_data():
    X, y, _ = make_linear_dataset(N, F, seed=0)
    return X, y


@pytest.fixture(scope="module")
def log_data(lin_data):
    X, y = lin_data
    return X, (y > np.median(y)).astype(np.float32)


def _fit_lin(system, X, y, version, **kw):
    return linreg.fit(system.put(X, y),
                      linreg.GdConfig(version=version, n_iters=30, **kw))


# ---------------------------------------------------------------------------
# Construction + identity.
# ---------------------------------------------------------------------------

def test_make_system_kinds():
    assert isinstance(make_system("pim", n_cores=4), PimSystem)
    assert isinstance(make_system("host"), HostSystem)
    gpu = make_system("gpu-model")
    assert isinstance(gpu, ModeledGpuSystem)
    assert isinstance(gpu, HostSystem)          # numerics by inheritance
    for kind, sys_ in (("pim", make_system("pim", n_cores=2)),
                       ("host", make_system("host")),
                       ("gpu-model", gpu)):
        assert isinstance(sys_, System)
        assert sys_.kind == kind
    with pytest.raises(ValueError, match="unknown system kind"):
        make_system("tpu")


def test_pim_system_move_is_behavior_preserving(lin_data):
    """The legacy import path IS the moved class, and an INT32 fit
    through it matches the new path bit for bit (the move cannot have
    forked the implementation)."""
    from repro.core.pim import PimConfig as OldCfg, PimSystem as OldSys
    from repro.systems.pim import PimSystem as NewSys
    assert OldSys is NewSys
    X, y = lin_data
    r_old = _fit_lin(OldSys(OldCfg(n_cores=CORES)), X, y, "int32")
    r_new = _fit_lin(make_system("pim", n_cores=CORES), X, y, "int32")
    assert np.array_equal(r_old.w, r_new.w) and r_old.b == r_new.b


def test_n_shards_semantics():
    assert make_system("pim", n_cores=4).n_shards == 4
    host = make_system("host", n_cores=4)     # 4 scheduling lanes...
    assert host.n_shards == 1                 # ...but ONE resident image
    x = np.arange(10, dtype=np.float32)
    assert host.shard_rows(x).shape == (1, 10)
    assert np.asarray(host.row_validity_mask(10)).all()


# ---------------------------------------------------------------------------
# Cross-system numeric parity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("version", ("fp32",))
def test_lin_fp32_host_matches_pim_fabric(lin_data, version):
    """fp32 GD on one resident image vs 8 fabric-reduced shards: same
    math, different summation order — float-tolerance equal."""
    X, y = lin_data
    r_pim = _fit_lin(make_system("pim", n_cores=CORES), X, y, version)
    r_host = _fit_lin(make_system("host"), X, y, version)
    np.testing.assert_allclose(r_host.w, r_pim.w, rtol=1e-4, atol=1e-5)
    assert r_host.b == pytest.approx(r_pim.b, rel=1e-4, abs=1e-5)


def test_log_fp32_host_matches_pim_within_tolerance(log_data):
    """Host fp32 uses the exact sigmoid, PIM fp32 the DPU Taylor
    expansion — decisions agree within tolerance (paper Fig. 7)."""
    X, y = log_data
    cfg = logreg.LogRegConfig(version="fp32", n_iters=40)
    r_pim = logreg.fit(make_system("pim", n_cores=CORES).put(X, y), cfg)
    r_host = logreg.fit(make_system("host").put(X, y), cfg)
    np.testing.assert_allclose(r_host.w, r_pim.w, rtol=5e-2, atol=5e-3)
    # the exact-vs-Taylor distinction is visible in the kernel registry
    host2 = make_system("host")
    logreg.fit(host2.put(X, y), cfg)
    assert any("fp32x" in k for k in host2.registered_kernels())


def test_integer_versions_run_unmodified_on_host(lin_data):
    """The quantized trainers are system-agnostic: int32 on a host
    target runs the identical integer math over one shard."""
    X, y = lin_data
    r_pim = _fit_lin(make_system("pim", n_cores=1), X, y, "int32")
    r_host = _fit_lin(make_system("host"), X, y, "int32")
    # one PIM core == one host image: the same serial reduction order,
    # the same integer bits
    assert np.array_equal(r_pim.w, r_host.w) and r_pim.b == r_host.b


def test_kmeans_fp32_host_vs_int16_pim(lin_data):
    """The fp32 K-Means version (the paper's float baseline) clusters
    like the quantized PIM version (ARI ~1, paper §5.1.4)."""
    from repro.core.metrics import adjusted_rand_index
    X, _, _ = make_blobs(400, 5, centers=4, seed=2)
    cfg = dict(k=4, max_iters=30, seed=1)
    r_pim = kmeans.fit(make_system("pim", n_cores=CORES).put(X),
                       kmeans.KMeansConfig(version="int16", **cfg))
    r_host = kmeans.fit(make_system("host").put(X),
                        kmeans.KMeansConfig(version="fp32", **cfg))
    assert adjusted_rand_index(r_pim.labels, r_host.labels) > 0.95
    np.testing.assert_allclose(r_host.centroids, r_pim.centroids,
                               rtol=0.05, atol=0.05)


def test_dtree_runs_on_all_three_systems():
    X, y = make_classification(512, 16, seed=4, class_sep=1.5)
    cfg = dtree.TreeConfig(max_depth=3, seed=0)
    trees = [dtree.fit(make_system(kind, n_cores=CORES).put(X, y), cfg)
             for kind in ("pim", "host", "gpu-model")]
    # same rng stream + exact integer split counts on every target:
    # identical trees
    for t in trees[1:]:
        assert t.n_nodes == trees[0].n_nodes
        assert np.array_equal(t.predict(X), trees[0].predict(X))


def test_gpu_model_returns_host_numerics_exactly(lin_data, log_data):
    """ModeledGpuSystem is HostSystem numerics + a roofline report —
    results must be IDENTICAL arrays, and the report must be filled."""
    X, y = lin_data
    r_host = _fit_lin(make_system("host"), X, y, "fp32")
    gpu = make_system("gpu-model")
    r_gpu = _fit_lin(gpu, X, y, "fp32")
    assert np.array_equal(r_host.w, r_gpu.w) and r_host.b == r_gpu.b
    assert gpu.gpu.launches == 30
    assert gpu.gpu.modeled_seconds > 0
    assert gpu.gpu.modeled_energy_j > 0
    # roofline floor: every launch pays the dispatch overhead
    assert gpu.gpu.modeled_seconds >= 30 * gpu.roofline.launch_overhead_s


# ---------------------------------------------------------------------------
# Per-system TransferStats semantics.
# ---------------------------------------------------------------------------

def test_hierarchical_reduce_on_host_keeps_pim_counters_zero(lin_data):
    """A hierarchical config on a host target (lane count divisible by
    the group size) must NOT leak the PIM-only rank->host counter: the
    strategy's byte accounting routes through the system hooks."""
    X, y = lin_data
    host = make_system("host", n_cores=8, reduce="hierarchical")
    linreg.fit(host.put(X, y), linreg.GdConfig(version="fp32", n_iters=3))
    assert host.stats.inter_core_via_host == 0
    assert host.stats.pim_to_cpu == 0 and host.stats.cpu_to_pim == 0


def test_host_stats_count_dram_not_transfers(lin_data):
    X, y = lin_data
    host = make_system("host")
    _fit_lin(host, X, y, "fp32")
    s = host.stats
    assert s.cpu_to_pim == 0 and s.pim_to_cpu == 0
    assert s.inter_core_via_host == 0
    # 30 launches x (X + y + mask + w + b) streamed from DRAM
    per_pass = X.size * 4 + y.size * 4 + N * 4 + F * 4 + 4
    assert s.dram_bytes == 30 * per_pass
    assert s.kernel_launches == 30 and s.host_syncs == 30
    assert s.shard_transfers == 2          # X and y views, paid once


def test_pim_stats_unchanged_by_refactor(lin_data):
    """The PIM byte accounting is exactly the pre-refactor arithmetic
    (the same closed-form the step-fusion tests pin)."""
    X, y = lin_data
    pim = make_system("pim", n_cores=CORES)
    ds = pim.put(X, y)
    cfg = linreg.GdConfig(version="int32", n_iters=5)
    linreg.fit(ds, cfg)
    snap = pim.stats.snapshot()
    linreg.fit(ds, cfg)
    d = pim.stats.delta(snap)
    assert d.dram_bytes == 0
    # per step: fabric reduce ships (gw:(F,), gb:()) int32 per core;
    # broadcast ships (w:(F,), b:()) int32 per core
    assert d.pim_to_cpu == 5 * (F + 1) * 4 * CORES
    assert d.cpu_to_pim == 5 * (F + 1) * 4 * CORES


def test_step_fusion_on_host_system(lin_data):
    """HostSystem fuses trivially (no reduce leg): one launch per
    chunk, bit-identical integer trajectory."""
    X, y = lin_data
    host1 = make_system("host")
    r1 = _fit_lin(host1, X, y, "int32")
    hostk = make_system("host")
    rk = _fit_lin(hostk, X, y, "int32", fuse_steps=8)
    assert np.array_equal(r1.w, rk.w) and r1.b == rk.b
    assert host1.stats.kernel_launches == 30
    assert hostk.stats.kernel_launches == 4      # chunks of 8,8,8,6
    assert hostk.stats.host_syncs == 4


# ---------------------------------------------------------------------------
# Scheduler: mixed PIM + host machine.
# ---------------------------------------------------------------------------

def test_scheduler_runs_mixed_pim_host_queue(lin_data):
    X, y = lin_data
    pim = PimSystem(PimConfig(n_cores=CORES))
    host = make_system("host", n_cores=4)
    sched = PimScheduler({"pim": pim, "host": host},
                         rank_size=CORES // 2)
    n_iters = 12
    h_pim = sched.submit("linreg", (X, y), version="int32",
                         n_iters=n_iters)
    h_host = sched.submit("linreg", (X, y), version="fp32",
                          n_iters=n_iters, target="host")
    h_kme = sched.submit("kmeans", (X, None), version="fp32",
                         n_clusters=3, max_iter=6, target="host")
    sched.drain()
    assert all(h.state is JobState.DONE for h in (h_pim, h_host, h_kme))
    assert (h_pim.target, h_host.target) == ("pim", "host")
    # attributable per-job deltas carry each target's OWN semantics
    assert h_pim.transfer.cpu_to_pim > 0 and h_pim.transfer.dram_bytes == 0
    assert h_host.transfer.dram_bytes > 0 and h_host.transfer.cpu_to_pim == 0
    assert h_host.transfer.kernel_launches == n_iters
    # DPU cycle accounting only applies to the PIM target
    assert h_pim.modeled_seconds > 0
    assert h_host.modeled_seconds == 0 and h_kme.modeled_seconds == 0
    # the host job matches a solo host fit bit for bit
    solo = linreg.fit(make_system("host").put(X, y),
                      linreg.GdConfig(version="fp32", n_iters=n_iters))
    assert np.array_equal(h_host.result.attributes["coef_"], solo.w)
    # per-target occupancy is visible and released
    st = sched.stats()
    assert set(st["targets"]) == {"pim", "host"}
    assert st["targets"]["host"]["cores_used"] == 0


def test_unknown_target_rejected(lin_data):
    X, y = lin_data
    sched = PimScheduler(PimSystem(PimConfig(n_cores=CORES)))
    with pytest.raises(ValueError, match="unknown target"):
        sched.submit("linreg", (X, y), version="int32", target="host")


def test_full_pim_machine_does_not_stall_host_admissions(lin_data):
    """Head-of-line blocking is per target on a mixed machine."""
    X, y = lin_data
    sched = PimScheduler({"pim": PimSystem(PimConfig(n_cores=CORES)),
                          "host": make_system("host", n_cores=2)},
                         rank_size=CORES)
    h1 = sched.submit("linreg", (X, y), version="int32", n_iters=4,
                      n_cores=CORES)
    h2 = sched.submit("linreg", (X, y), version="int32", n_iters=4,
                      n_cores=CORES)          # queued behind h1
    h3 = sched.submit("linreg", (X, y), version="fp32", n_iters=4,
                      target="host")
    sched.step()
    # h2 cannot start (machine full) but the host job was admitted
    assert h1.state is JobState.RUNNING
    assert h2.state is JobState.QUEUED
    assert h3.state is JobState.RUNNING
    sched.drain()
    assert all(h.state is JobState.DONE for h in (h1, h2, h3))


# ---------------------------------------------------------------------------
# The compare driver (acceptance: all four workloads, three systems).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_compare_tiny_produces_three_way_table(tmp_path):
    from repro.launch import compare
    record = compare.main(["--tiny", "--cores", "4",
                           "--out", str(tmp_path / "compare.json")])
    with open(tmp_path / "compare.json") as fh:
        on_disk = json.load(fh)
    assert on_disk["meta"]["systems"] == ["pim", "host", "gpu-model"]
    rows = record["rows"]
    seen = {(r["workload"], r["system"]) for r in rows}
    assert seen == {(w, s)
                    for w in ("linreg", "logreg", "dtree", "kmeans", "emb")
                    for s in ("pim", "host", "gpu-model")}
    for r in rows:
        assert r["modeled_s"] > 0 and r["wall_s"] >= 0
    # host and gpu-model rows share numerics -> identical scores
    by_key = {(r["workload"], r["system"]): r for r in rows}
    for w in ("linreg", "logreg", "dtree", "kmeans", "emb"):
        assert by_key[(w, "host")]["score"] == \
            by_key[(w, "gpu-model")]["score"]


# ---------------------------------------------------------------------------
# Legacy PimSystem-only call paths: one DeprecationWarning, identical
# results (pattern from tests/test_deprecation.py).
# ---------------------------------------------------------------------------

def _deprecations(fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        result = fn()
    return result, [w for w in rec
                    if issubclass(w.category, DeprecationWarning)]


def test_make_estimator_pim_kwarg_warns_once_and_matches(lin_data):
    X, y = lin_data
    pim = PimSystem(PimConfig(n_cores=CORES))
    est, deps = _deprecations(
        lambda: make_estimator("linreg", version="int32", n_iters=10,
                               pim=pim))
    assert len(deps) == 1 and "system=" in str(deps[0].message)
    _, deps_fit = _deprecations(lambda: est.fit(X, y))
    assert len(deps_fit) == 0
    modern = make_estimator("linreg", version="int32", n_iters=10,
                            system=PimSystem(PimConfig(n_cores=CORES))
                            ).fit(X, y)
    assert np.array_equal(est.coef_, modern.coef_)
    assert est.intercept_ == modern.intercept_
    # the deprecated alias attribute still reads (and is the system)
    assert est.pim is est.system


def test_set_params_pim_kwarg_warns_once(lin_data):
    est = make_estimator("linreg", version="int32", n_iters=5)
    other = PimSystem(PimConfig(n_cores=4))
    _, deps = _deprecations(lambda: est.set_params(pim=other))
    assert len(deps) == 1
    assert est.system is other and est.n_cores == 4


def test_train_wrappers_accept_any_system(lin_data):
    """The deprecated train(...) shims are System-generic now: a
    HostSystem flows through with the same single warning."""
    X, y = lin_data
    host = make_system("host")
    r_legacy, deps = _deprecations(
        lambda: linreg.train(X, y, host,
                             linreg.GdConfig(version="fp32", n_iters=8)))
    assert len(deps) == 1
    r_new = linreg.fit(make_system("host").put(X, y),
                       linreg.GdConfig(version="fp32", n_iters=8))
    assert np.array_equal(r_legacy.w, r_new.w) and r_legacy.b == r_new.b


# ---------------------------------------------------------------------------
# Estimator + registry integration.
# ---------------------------------------------------------------------------

def test_estimator_system_kwarg_and_adoption(lin_data):
    X, y = lin_data
    host = make_system("host")
    est = make_estimator("linreg", version="fp32", n_iters=10,
                         system=host).fit(X, y)
    assert est.system is host
    # fitting a dataset adopts ITS system (here: a different target)
    pim = PimSystem(PimConfig(n_cores=CORES))
    est.fit(pim.put(X, y))
    assert est.system is pim


def test_estimator_rejects_y_with_dataset(lin_data):
    X, y = lin_data
    host = make_system("host")
    ds = host.put(X, y)
    with pytest.raises(ValueError, match="System.put"):
        make_estimator("linreg", system=host).fit(ds, y)


def test_kmeans_fp32_version_via_registry():
    X, _, _ = make_blobs(300, 4, centers=3, seed=5)
    est = make_estimator("kmeans", version="fp32", n_clusters=3,
                         max_iter=10,
                         system=make_system("host")).fit(X)
    assert est.cluster_centers_.shape == (3, 4)
    assert get_workload("kmeans").versions == ("int16", "fp32")


@pytest.mark.slow
def test_compare_rerun_other_cores_and_shape_table(tmp_path):
    """The compare driver re-run at a different core count/seed stays
    complete, and the non-tiny shape table is well-formed (the full
    shapes themselves run via `make bench` — fig13_17_compare)."""
    from repro.launch.compare import _shapes, run_compare
    record = run_compare(tiny=True, cores=8, seed=1)
    assert len(record["rows"]) == 15
    full = _shapes(tiny=False)
    assert set(full) == {"linreg", "logreg", "dtree", "kmeans", "emb"}
    for n, f, params in full.values():
        assert n > 0 and f > 0 and params
