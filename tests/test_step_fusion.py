"""Step-fusion engine (core/pim.py StepProgram; DESIGN.md §9).

Covers fused-vs-serial bit identity for every integer trainer version,
float closeness for fp32/K-Means, chunk-boundary ``record_every``
equivalence, the analytic TransferStats chunk accounting (k=32 chunk ==
ONE kernel launch — the CI assertion), HostReduce degradation, and
scheduler integration with mixed fused/unfused jobs; the large-k and
fused-gang cases are marked ``slow``.
"""
import numpy as np
import pytest

from repro.api import PimConfig, PimSystem, make_estimator
from repro.core import kmeans, linreg, logreg
from repro.core.pim import HierarchicalReduce, ReduceVia
from repro.data.synthetic import make_blobs, make_linear_dataset
from repro.sched import JobState, PimScheduler

N, F, CORES = 256, 6, 8


@pytest.fixture(scope="module")
def lin_data():
    X, y, _ = make_linear_dataset(N, F, seed=0)
    return X, y


@pytest.fixture(scope="module")
def log_data(lin_data):
    X, y = lin_data
    return X, (y > np.median(y)).astype(np.float32)


def _lin_pair(X, y, ver, fuse, n_iters=40, **kw):
    pim = PimSystem(PimConfig(n_cores=CORES, **kw.pop("pim_kw", {})))
    ds = pim.put(X, y)
    cfg = linreg.GdConfig(version=ver, n_iters=n_iters, fuse_steps=fuse,
                          **kw)
    return linreg.fit(ds, cfg), pim


# ---------------------------------------------------------------------------
# Acceptance: fused == serial, bit for bit, for every integer version.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ver", ("int32", "hyb", "bui"))
def test_lin_fused_bit_identical(lin_data, ver):
    X, y = lin_data
    r1, _ = _lin_pair(X, y, ver, fuse=1)
    rk, _ = _lin_pair(X, y, ver, fuse=8)
    assert np.array_equal(r1.w, rk.w)
    assert r1.b == rk.b


def test_lin_fp32_fused_close(lin_data):
    X, y = lin_data
    r1, _ = _lin_pair(X, y, "fp32", fuse=1)
    rk, _ = _lin_pair(X, y, "fp32", fuse=8)
    np.testing.assert_allclose(r1.w, rk.w, rtol=1e-5, atol=1e-6)
    assert r1.b == pytest.approx(rk.b, rel=1e-5, abs=1e-6)


@pytest.mark.parametrize("ver", ("int32", "int32_lut_wram", "hyb_lut",
                                 "bui_lut"))
def test_log_fused_bit_identical(log_data, ver):
    X, y = log_data
    results = []
    for fuse in (1, 8):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(X, y)
        results.append(logreg.fit(ds, logreg.LogRegConfig(
            version=ver, n_iters=30, fuse_steps=fuse)))
    assert np.array_equal(results[0].w, results[1].w)
    assert results[0].b == results[1].b


def test_kmeans_fused_inertia_close():
    Xb, _, _ = make_blobs(300, 4, centers=5, seed=1)
    results = []
    for fuse in (1, 8):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(Xb)
        results.append(kmeans.fit(ds, kmeans.KMeansConfig(
            k=5, max_iters=40, seed=3, fuse_steps=fuse)))
    r1, rk = results
    assert rk.inertia == pytest.approx(r1.inertia, rel=1e-4)
    assert rk.n_iters == r1.n_iters       # on-device done flag matches
    np.testing.assert_allclose(r1.centroids, rk.centroids,
                               rtol=1e-4, atol=1e-3)


def test_fused_partial_tail_chunk(lin_data):
    """n_iters not divisible by fuse_steps: the tail chunk is clipped,
    total iterations exact."""
    X, y = lin_data
    r1, _ = _lin_pair(X, y, "int32", fuse=1, n_iters=21)
    rk, _ = _lin_pair(X, y, "int32", fuse=8, n_iters=21)
    assert np.array_equal(r1.w, rk.w) and r1.b == rk.b


# ---------------------------------------------------------------------------
# record_every lands on chunk boundaries with identical history.
# ---------------------------------------------------------------------------

def test_record_every_chunk_boundary_equivalence(lin_data):
    X, y = lin_data

    def run(fuse):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(X, y)
        cfg = linreg.GdConfig(version="int32", n_iters=25, fuse_steps=fuse,
                              record_every=10)
        return linreg.fit(ds, cfg,
                          eval_fn=lambda w, b: (w.copy(), float(b)))

    r1, rk = run(1), run(8)
    assert [it for it, _ in r1.history] == [it for it, _ in rk.history] \
        == [10, 20, 25]
    for (_, (w1, b1)), (_, (wk, bk)) in zip(r1.history, rk.history):
        assert np.array_equal(w1, wk) and b1 == bk


# ---------------------------------------------------------------------------
# TransferStats chunk accounting.
# ---------------------------------------------------------------------------

def test_k32_chunk_is_one_launch_one_sync(lin_data):
    """THE fusion assertion (scripts/ci.sh): a k=32 chunk is ONE
    host-issued kernel launch and ONE host sync."""
    X, y = lin_data
    pim = PimSystem(PimConfig(n_cores=CORES))
    ds = pim.put(X, y)
    linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=32,
                                   fuse_steps=32))  # warm the view cache
    snap = pim.stats.snapshot()
    linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=32,
                                   fuse_steps=32))
    d = pim.stats.delta(snap)
    assert d.kernel_launches == 1
    assert d.host_syncs == 1


def test_unfused_counts_one_launch_per_step(lin_data):
    X, y = lin_data
    pim = PimSystem(PimConfig(n_cores=CORES))
    ds = pim.put(X, y)
    n_iters = 12
    linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=n_iters))
    snap = pim.stats.snapshot()
    linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=n_iters))
    d = pim.stats.delta(snap)
    assert d.kernel_launches == n_iters
    assert d.host_syncs == n_iters


def test_chunk_reduce_bytes_scale_k_times(lin_data):
    """The fabric reduce still moves k x the single-step bytes per
    chunk; only the sync count and broadcast bytes collapse."""
    X, y = lin_data
    k = 8

    def deltas(fuse):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(X, y)
        cfg = linreg.GdConfig(version="int32", n_iters=k, fuse_steps=fuse)
        linreg.fit(ds, cfg)
        snap = pim.stats.snapshot()
        linreg.fit(ds, cfg)
        return pim.stats.delta(snap)

    du, df = deltas(1), deltas(k)
    # per-step reduce legs: identical byte totals (k x single-step)...
    assert df.pim_to_cpu >= du.pim_to_cpu
    # ...up to the single chunk-boundary sync of carry + emits
    assert df.pim_to_cpu - du.pim_to_cpu <= (F + 2) * 4
    # broadcasts collapse: one carry broadcast per chunk vs k per-step
    assert df.cpu_to_pim < du.cpu_to_pim
    assert df.host_syncs == 1 and du.host_syncs == k


def test_chunk_accounting_not_cached_across_widths(lin_data):
    """Two same-n datasets of different width on ONE system produce
    same-named programs; the reduce-leg byte accounting must follow
    each dataset's true shapes, not a stale cached eval_shape."""
    k = 8
    pim = PimSystem(PimConfig(n_cores=CORES))
    for feat in (4, 12):
        X, y, _ = make_linear_dataset(N, feat, seed=1)
        ds = pim.put(X, y)
        cfg = linreg.GdConfig(version="int32", n_iters=k, fuse_steps=k)
        linreg.fit(ds, cfg)
        snap = pim.stats.snapshot()
        linreg.fit(ds, cfg)
        d = pim.stats.delta(snap)
        # fabric reduce legs: k x (gw:(F,), gb:()) int32 x n_cores,
        # plus the chunk-boundary sync of the (w, b, s) carry
        assert d.pim_to_cpu == k * (feat + 1) * 4 * CORES + (feat + 2) * 4


def test_hierarchical_chunk_accounting(lin_data):
    """HierarchicalReduce fuses fully on device; the modeled rank->host
    leg still accrues k x per-step bytes (inter_core_via_host)."""
    X, y = lin_data
    k = 6
    pim = PimSystem(PimConfig(n_cores=CORES,
                              reduce=ReduceVia.HIERARCHICAL))
    ds = pim.put(X, y)
    cfg = linreg.GdConfig(version="int32", n_iters=k, fuse_steps=k)
    linreg.fit(ds, cfg)
    snap = pim.stats.snapshot()
    r = linreg.fit(ds, cfg)
    d = pim.stats.delta(snap)
    assert d.kernel_launches == 1
    # HierarchicalReduce(8) on 8 cores -> 1 group; per-step rank
    # partials: (1, F) int32 gw + (1,) int32 gb
    per_step = (F + 1) * 4
    assert d.inter_core_via_host == k * per_step
    # matches the unfused hierarchical trajectory bit for bit
    pim2 = PimSystem(PimConfig(n_cores=CORES,
                               reduce=ReduceVia.HIERARCHICAL))
    r2 = linreg.fit(pim2.put(X, y),
                    linreg.GdConfig(version="int32", n_iters=k))
    assert np.array_equal(r.w, r2.w) and r.b == r2.b


def test_host_reduce_degrades_to_per_step(lin_data):
    """HostReduce cannot fuse (the reduce IS a host round trip): the
    chunk runs as k single steps with unfused accounting — and stays
    bit-identical."""
    X, y = lin_data
    k = 6

    def run(fuse):
        pim = PimSystem(PimConfig(n_cores=CORES, reduce=ReduceVia.HOST))
        ds = pim.put(X, y)
        cfg = linreg.GdConfig(version="int32", n_iters=k, fuse_steps=fuse)
        r = linreg.fit(ds, cfg)
        snap = pim.stats.snapshot()
        r = linreg.fit(ds, cfg)
        return r, pim.stats.delta(snap)

    r1, d1 = run(1)
    rk, dk = run(k)
    assert np.array_equal(r1.w, rk.w) and r1.b == rk.b
    assert dk.kernel_launches == d1.kernel_launches == k
    assert dk.host_syncs == d1.host_syncs == k


def test_minibatch_fuses_with_offset_scan_xs(lin_data):
    """Minibatch SGD no longer falls back (DESIGN.md §9.5): each chunk's
    batch offsets are pre-drawn from the serial loop's rng stream and
    fed through the scan as xs — bit-identical trajectory, and the
    launch count collapses to one per chunk."""
    X, y = lin_data
    r1, p1 = _lin_pair(X, y, "int32", fuse=1, n_iters=10, minibatch=8,
                       seed=7)
    rk, pk = _lin_pair(X, y, "int32", fuse=8, n_iters=10, minibatch=8,
                       seed=7)
    assert np.array_equal(r1.w, rk.w) and r1.b == rk.b
    # 10 iterations at fuse_steps=8 -> chunks of 8 + 2: TWO launches
    # (and syncs) where the serial SGD loop pays ten of each
    assert p1.stats.kernel_launches == 10 and p1.stats.host_syncs == 10
    assert pk.stats.kernel_launches == 2 and pk.stats.host_syncs == 2


@pytest.mark.parametrize("ver", ("int32", "hyb"))
def test_minibatch_fused_bit_identical_versions(lin_data, ver):
    """Fused minibatch SGD == serial minibatch SGD, bit for bit, with a
    non-dividing tail chunk and record_every landing mid-stream."""
    X, y = lin_data
    kw = dict(n_iters=21, minibatch=8, seed=3, record_every=10)
    r1, _ = _lin_pair(X, y, ver, fuse=1, **kw)
    rk, _ = _lin_pair(X, y, ver, fuse=8, **kw)
    assert np.array_equal(r1.w, rk.w) and r1.b == rk.b


# ---------------------------------------------------------------------------
# API + scheduler integration.
# ---------------------------------------------------------------------------

def test_estimator_exposes_fuse_steps(lin_data):
    X, y = lin_data
    e1 = make_estimator("linreg", version="int32", n_iters=30,
                        n_cores=CORES).fit(X, y)
    ek = make_estimator("linreg", version="int32", n_iters=30,
                        fuse_steps=8, n_cores=CORES).fit(X, y)
    assert ek.get_params()["fuse_steps"] == 8
    assert np.array_equal(e1.coef_, ek.coef_)


def test_scheduler_mixed_fused_unfused_jobs(lin_data):
    """A fused-chunk job and a per-step job interleave in one queue;
    both finish, chunk accounting is attributable, results match solo
    fits bit for bit."""
    X, y = lin_data
    system = PimSystem(PimConfig(n_cores=CORES))
    sched = PimScheduler(system, rank_size=CORES // 2)
    n_iters = 24
    hf = sched.submit("linreg", (X, y), version="int32", n_iters=n_iters,
                      fuse_steps=8)
    hu = sched.submit("linreg", (X, y), version="int32", n_iters=n_iters)
    sched.drain()
    assert hf.state is JobState.DONE and hu.state is JobState.DONE
    assert np.array_equal(hf.result.attributes["coef_"],
                          hu.result.attributes["coef_"])
    # the fused job took 3 chunk turns covering 24 iterations
    assert hf.steps == 3 and hf.iters == n_iters
    assert hu.steps == n_iters and hu.iters == n_iters
    assert hf.transfer.kernel_launches == 3
    assert hu.transfer.kernel_launches == n_iters
    # per-iteration cost-model accounting matches across the two modes
    assert hf.modeled_seconds == pytest.approx(hu.modeled_seconds)


@pytest.mark.slow
def test_fused_gang_with_step_chunks_matches_serial(lin_data):
    """Lane fusion x step fusion: a fused lr-sweep gang whose specs
    carry fuse_steps advances K lanes x k steps per launch and stays
    bit-identical to serial unfused fits."""
    X, y = lin_data
    lrs = [0.05, 0.1, 0.2]
    n_iters = 40

    def sweep(fuse_steps):
        system = PimSystem(PimConfig(n_cores=CORES))
        sched = PimScheduler(system, rank_size=CORES)
        snap = system.stats.snapshot()
        hs = sched.sweep("linreg", (X, y), {"lr": lrs}, version="int32",
                         n_iters=n_iters, fuse_steps=fuse_steps,
                         n_cores=CORES, fused=True)
        sched.drain()
        assert all(h.state is JobState.DONE and h.fused for h in hs)
        return hs, system.stats.delta(snap)

    serial, _ = sweep(1)
    chunked, d = sweep(8)
    # K lanes x 8 steps per launch: 5 launches for the 40-iter sweep
    assert d.kernel_launches == n_iters // 8
    for hs, hc in zip(serial, chunked):
        assert np.array_equal(hs.result.attributes["coef_"],
                              hc.result.attributes["coef_"])
        assert hs.result.attributes["intercept_"] \
            == hc.result.attributes["intercept_"]


def test_chunked_gang_lane_cancel(lin_data):
    """Cancelling a lane between chunks rebuilds the device carry with
    the new active mask: the cancelled lane freezes, survivors finish
    bit-identical to their solo fused fits."""
    from repro.api import get_workload
    from repro.sched.gang import FusedGdSweep
    X, y = lin_data
    wl = get_workload("linreg")
    system = PimSystem(PimConfig(n_cores=CORES))
    ds = system.put(X, y)
    lrs = [0.05, 0.1, 0.2]
    specs = [wl.spec("int32", lr=lr, n_iters=24, fuse_steps=8)
             for lr in lrs]
    gang = FusedGdSweep(wl, specs, ds)
    gang.step()                          # chunk 1 (iters 1-8)
    gang.deactivate(1)
    frozen = gang.w[1].copy()
    while not gang.step():
        pass
    assert gang.result(1) is None
    assert np.array_equal(gang.w[1], frozen)     # froze at cancellation
    for lane in (0, 2):
        solo = linreg.fit(ds, linreg.GdConfig(
            version="int32", n_iters=24, lr=lrs[lane], fuse_steps=8))
        r = gang.result(lane)
        assert np.array_equal(r.model.w, solo.w)
        assert r.model.b == solo.b


@pytest.mark.slow
def test_large_k_long_run_bit_identical(lin_data):
    """500 iterations at fuse_steps=64 (tail chunk included) stays bit-
    identical to the serial loop for every integer LIN version."""
    X, y = lin_data
    for ver in ("int32", "hyb"):
        r1, _ = _lin_pair(X, y, ver, fuse=1, n_iters=500)
        rk, _ = _lin_pair(X, y, ver, fuse=64, n_iters=500)
        assert np.array_equal(r1.w, rk.w) and r1.b == rk.b


# ---------------------------------------------------------------------------
# Chunk pipelining (DESIGN.md §14.1): depth only reorders host work.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", (1, 2, 3))
@pytest.mark.parametrize("ver", ("int32", "hyb", "bui"))
def test_lin_pipeline_depth_bit_identical(lin_data, ver, depth):
    """Any in-flight depth must equal the serial dispatch-drain cadence
    bit for bit — weights, bias, AND the recorded history (the drain
    side is where pipelining reorders work)."""
    X, y = lin_data
    ref, _ = _lin_pair(X, y, ver, fuse=8, record_every=8,
                       pipeline_depth=1)
    r, _ = _lin_pair(X, y, ver, fuse=8, record_every=8,
                     pipeline_depth=depth)
    assert np.array_equal(ref.w, r.w)
    assert ref.b == r.b
    assert ref.history == r.history


def test_lin_pipeline_eval_fn_order(lin_data):
    """eval_fn fires once per boundary, in chunk order, with the
    boundary's own dequantized coefficients — regardless of depth."""
    X, y = lin_data
    traces = {}
    for depth in (1, 2):
        trace = []
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(X, y)
        cfg = linreg.GdConfig(version="int32", n_iters=32, fuse_steps=8,
                              record_every=8, pipeline_depth=depth)
        linreg.fit(ds, cfg, eval_fn=lambda w, b, t=trace: (
            t.append((w.tobytes(), b)), 0.0)[1])
        traces[depth] = trace
    assert len(traces[1]) == 4
    assert traces[1] == traces[2]


@pytest.mark.parametrize("ver", ("int32", "int32_lut_mram",
                                 "int32_lut_wram", "hyb_lut", "bui_lut"))
def test_log_pipeline_bit_identical(log_data, ver):
    X, y = log_data
    results = {}
    for depth in (1, 2):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(X, y)
        cfg = logreg.LogRegConfig(version=ver, n_iters=32,
                                  fuse_steps=8, record_every=8,
                                  pipeline_depth=depth)
        results[depth] = logreg.fit(ds, cfg)
    assert np.array_equal(results[1].w, results[2].w)
    assert results[1].b == results[2].b
    assert results[1].history == results[2].history


def test_kmeans_pipeline_bit_identical():
    Xb, _, _ = make_blobs(N, F, centers=4, seed=1)
    results = {}
    for depth in (1, 2):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(Xb)
        # tol=0 runs Lloyd's to max_iters so every chunk executes
        cfg = kmeans.KMeansConfig(k=4, max_iters=12, tol=0.0, seed=3,
                                  fuse_steps=4, pipeline_depth=depth)
        results[depth] = kmeans.fit(ds, cfg, return_labels=False)
    assert np.array_equal(results[1].centroids, results[2].centroids)
    assert results[1].inertia == results[2].inertia
    assert results[1].n_iters == results[2].n_iters


def test_kmeans_pipeline_early_convergence():
    """The done-latch must discard speculative in-flight chunks: a run
    that converges mid-pipeline stops at the same iteration as the
    serial cadence."""
    Xb, _, _ = make_blobs(N, F, centers=4, seed=1)
    results = {}
    for depth in (1, 3):
        pim = PimSystem(PimConfig(n_cores=CORES))
        ds = pim.put(Xb)
        cfg = kmeans.KMeansConfig(k=4, max_iters=40, tol=1e-4, seed=3,
                                  fuse_steps=2, pipeline_depth=depth)
        results[depth] = kmeans.fit(ds, cfg, return_labels=False)
    assert results[1].n_iters == results[3].n_iters < 40
    assert np.array_equal(results[1].centroids, results[3].centroids)


def test_minibatch_pipeline_bit_identical(lin_data):
    """Pipelined dispatch pre-draws each chunk's batch offsets eagerly;
    the rng stream consumption must still match the serial cadence."""
    X, y = lin_data
    ref, _ = _lin_pair(X, y, "int32", fuse=4, n_iters=32, minibatch=32,
                       record_every=4, pipeline_depth=1)
    r, _ = _lin_pair(X, y, "int32", fuse=4, n_iters=32, minibatch=32,
                     record_every=4, pipeline_depth=2)
    assert np.array_equal(ref.w, r.w)
    assert ref.b == r.b
    assert ref.history == r.history


def test_scheduler_gang_pipeline_bit_identical(lin_data):
    """Two fused jobs gang-stepped by the scheduler with depth-2
    pipelines match their solo depth-1 fits."""
    X, y = lin_data
    sched = PimScheduler(PimSystem(PimConfig(n_cores=CORES)), rank_size=4)
    handles = [sched.submit("linreg", (X, y), version="int32",
                            n_cores=4, lr=lr, n_iters=24, fuse_steps=8,
                            pipeline_depth=2)
               for lr in (0.05, 0.2)]
    sched.drain()
    for h, lr in zip(handles, (0.05, 0.2)):
        assert h.state is JobState.DONE
        pim = PimSystem(PimConfig(n_cores=4))
        solo = linreg.fit(pim.put(X, y), linreg.GdConfig(
            version="int32", lr=lr, n_iters=24, fuse_steps=8,
            pipeline_depth=1))
        assert np.array_equal(np.asarray(h.result.model.w), solo.w)
        assert float(h.result.model.b) == solo.b


def test_preempt_resume_mid_pipeline_bit_identical(lin_data):
    """Preemption at a chunk boundary while chunks are in flight:
    the snapshot is drain-authoritative, and resuming on a fresh
    scheduler completes bit-identically to an uninterrupted fit."""
    X, y = lin_data
    params = dict(version="int32", n_iters=32, fuse_steps=4,
                  pipeline_depth=2)
    pim = PimSystem(PimConfig(n_cores=4))
    ref = linreg.fit(pim.put(X, y), linreg.GdConfig(**params))

    sched = PimScheduler(PimSystem(PimConfig(n_cores=CORES)), rank_size=4)
    h = sched.submit("linreg", (X, y), n_cores=4, **params)
    sched.step(); sched.step()
    h.preempt()
    sched.step()
    assert h.state is JobState.PREEMPTED
    assert 0 < h.iters < 32
    assert h.iters % 4 == 0            # snapshot on a chunk boundary

    s2 = PimScheduler(PimSystem(PimConfig(n_cores=CORES)), rank_size=4)
    s2.resume(h, data=(X, y))
    s2.drain()
    assert h.state is JobState.DONE and h.iters == 32
    assert np.array_equal(np.asarray(h.result.model.w), ref.w)
    assert float(h.result.model.b) == ref.b
