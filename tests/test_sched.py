"""Multi-tenant PIM job scheduler (repro/sched; DESIGN.md §7).

Covers the allocator invariants, PimSlice scoping, the gang-stepped
queue (lifecycle, priority, failure isolation, per-job transfer
deltas), and — in the ``slow``-marked cases — fused sweeps and large-K
queues.
"""
import json

import numpy as np
import pytest

from repro.api import PimConfig, PimSystem, Workload, make_estimator
from repro.data.synthetic import make_blobs, make_linear_dataset
from repro.sched import (BankAllocator, JobState, PimScheduler, PimSlice,
                         fuse_key, job_report, plan_fusion, run_manifest)
from repro.sched.allocator import BankLease


# ---------------------------------------------------------------------------
# BankAllocator invariants.
# ---------------------------------------------------------------------------

def test_allocator_first_fit_rank_alignment():
    alloc = BankAllocator(64, rank_size=16)
    a = alloc.allocate(10)           # rounds up to one 16-core rank
    b = alloc.allocate(17)           # rounds up to two ranks
    assert (a.start, a.n_cores) == (0, 16)
    assert (b.start, b.n_cores) == (16, 32)
    assert a.start % 16 == 0 and b.start % 16 == 0
    assert alloc.allocate(32) is None     # only 16 cores left
    c = alloc.allocate(None)              # default: one rank
    assert (c.start, c.n_cores) == (48, 16)
    assert alloc.free_cores == 0


def test_allocator_release_coalesces_free_extents():
    alloc = BankAllocator(32, rank_size=8)
    leases = [alloc.allocate(8) for _ in range(4)]
    # free the middle two in reverse order: must coalesce with each other
    alloc.release(leases[2])
    alloc.release(leases[1])
    frag = alloc.fragmentation()
    assert frag.free_cores == 16
    assert frag.n_free_extents == 1
    assert frag.largest_free_extent == 16
    assert frag.external_fragmentation == 0.0
    alloc.release(leases[0])
    alloc.release(leases[3])
    assert alloc.fragmentation().n_free_extents == 1
    assert alloc.free_cores == 32


def test_allocator_fragmentation_visible():
    alloc = BankAllocator(32, rank_size=8)
    leases = [alloc.allocate(8) for _ in range(4)]
    alloc.release(leases[0])
    alloc.release(leases[2])          # two disjoint 8-core holes
    frag = alloc.fragmentation()
    assert frag.free_cores == 16 and frag.n_free_extents == 2
    assert frag.external_fragmentation == pytest.approx(0.5)
    # 16 free cores but no 16-core hole
    assert alloc.allocate(16) is None


def test_allocator_auto_rank_on_awkward_machine_sizes():
    """The default rank clamps to the largest divisor of the machine
    <= UPMEM's 64 — a 96-core scheduler must construct out of the box."""
    from repro.sched import default_rank_size
    assert default_rank_size(96) == 48
    assert default_rank_size(100) == 50
    assert default_rank_size(128) == 64
    assert default_rank_size(7) == 7
    assert BankAllocator(96).rank_size == 48
    sched = PimScheduler(PimSystem(PimConfig(n_cores=96)))
    assert sched.allocator.rank_size == 48


def test_allocator_rejects_bad_requests():
    alloc = BankAllocator(16, rank_size=4)
    with pytest.raises(ValueError):
        alloc.allocate(17)            # larger than the machine
    with pytest.raises(ValueError):
        alloc.allocate(0)
    with pytest.raises(ValueError):
        alloc.release(BankLease(0, 4))  # never granted
    with pytest.raises(ValueError):
        BankAllocator(16, rank_size=5)  # rank must divide cores


# ---------------------------------------------------------------------------
# PimSlice scoping.
# ---------------------------------------------------------------------------

def test_slice_scopes_shards_and_mirrors_stats():
    parent = PimSystem(PimConfig(n_cores=16))
    sl = PimSlice(parent, BankLease(4, 4))
    assert sl.config.n_cores == 4
    xs = sl.shard_rows(np.arange(12, dtype=np.float32))
    assert xs.shape == (4, 3)                      # sliced, not parent, width
    assert sl.stats.cpu_to_pim == xs.nbytes
    assert parent.stats.cpu_to_pim == xs.nbytes    # mirrored increment
    sl.stats.reset()                               # slice-local only
    assert sl.stats.cpu_to_pim == 0
    assert parent.stats.cpu_to_pim == xs.nbytes    # parent keeps cumulative


def test_slice_lease_must_fit_parent():
    parent = PimSystem(PimConfig(n_cores=8))
    with pytest.raises(ValueError):
        PimSlice(parent, BankLease(4, 8))


# ---------------------------------------------------------------------------
# Acceptance (a): disjoint slices == whole-mesh serial, bit for bit.
# ---------------------------------------------------------------------------

def test_disjoint_slices_bit_identical_to_whole_mesh():
    X, y, _ = make_linear_dataset(512, 8, seed=0)
    Xb, _, _ = make_blobs(512, 4, centers=4, seed=1)

    system = PimSystem(PimConfig(n_cores=16))
    sched = PimScheduler(system, rank_size=4)
    h_lin = sched.submit("linreg", (X, y), version="int32", n_iters=15,
                         n_cores=4)
    h_kme = sched.submit("kmeans", Xb, n_clusters=4, max_iter=8,
                         n_cores=8)
    sched.drain()
    assert h_lin.state is JobState.DONE and h_kme.state is JobState.DONE
    # the two jobs really ran concurrently on disjoint extents
    assert h_lin.lease.stop <= h_kme.lease.start \
        or h_kme.lease.stop <= h_lin.lease.start

    ref = PimSystem(PimConfig(n_cores=16))
    ref_lin = make_estimator("linreg", version="int32", n_iters=15,
                             system=ref).fit(ref.put(X, y))
    ref_kme = make_estimator("kmeans", n_clusters=4, max_iter=8,
                             system=ref).fit(Xb)
    # integer GD / integer Lloyd's are partition-invariant: the sliced
    # fits must equal the whole-mesh fits bit for bit
    assert np.array_equal(h_lin.result.attributes["coef_"], ref_lin.coef_)
    assert h_lin.result.attributes["intercept_"] == ref_lin.intercept_
    assert np.array_equal(h_kme.result.attributes["cluster_centers_"],
                          ref_kme.cluster_centers_)
    assert np.array_equal(h_kme.result.attributes["labels_"],
                          ref_kme.labels_)
    # inertia is accumulated in float32 per core (int32 would overflow,
    # see kmeans._inertia_kernel_factory) so it is partition-dependent
    # rounding noise, not part of the bit-exact fit
    assert h_kme.result.attributes["inertia_"] \
        == pytest.approx(ref_kme.inertia_, rel=1e-6)


# ---------------------------------------------------------------------------
# Acceptance (b): mixed queue, per-job deltas, failure isolation.
# ---------------------------------------------------------------------------

def test_mixed_queue_drains_with_per_job_deltas_and_isolation():
    """K=8 mixed LIN/LOG/KME queue; one job forced to raise mid-queue
    leaves the other seven DONE with attributable transfer deltas."""
    X, y, _ = make_linear_dataset(256, 8, seed=0)
    Xb, _, _ = make_blobs(256, 4, centers=4, seed=1)
    n_iters = 12

    system = PimSystem(PimConfig(n_cores=16))
    sched = PimScheduler(system, rank_size=4)
    handles = [
        sched.submit("linreg", (X, y), version="int32", n_iters=n_iters),
        sched.submit("linreg", (X, y), version="hyb", n_iters=n_iters),
        sched.submit("logreg", (X, y), version="int32", n_iters=n_iters),
        sched.submit("kmeans", Xb, n_clusters=4, max_iter=10),
        # forced failure: more clusters than points raises inside fit
        sched.submit("kmeans", Xb[:3], n_clusters=8, name="poison"),
        sched.submit("logreg", (X, y), version="int32_lut_wram",
                     n_iters=n_iters),
        sched.submit("linreg", (X, y), version="fp32", n_iters=n_iters),
        sched.submit("kmeans", Xb, n_clusters=4, max_iter=10, seed=7),
    ]
    assert len(handles) == 8
    sched.drain()

    poison = handles[4]
    assert poison.state is JobState.FAILED
    assert isinstance(poison.error, ValueError)
    others = [h for h in handles if h is not poison]
    assert all(h.state is JobState.DONE for h in others)

    # per-job transfer deltas are attributable and correct even though
    # the jobs interleaved on one system:
    for h in handles[:3] + [handles[5], handles[6]]:     # LIN/LOG jobs
        assert h.transfer.kernel_launches == n_iters     # 1 per GD step
        assert h.transfer.shard_transfers == 2           # X and y views
    for h in (handles[3], handles[7]):                   # KME jobs
        # one launch per Lloyd step + inertia + labels passes
        assert h.transfer.kernel_launches == h.steps + 2
        assert h.transfer.shard_transfers == 1           # X view only
    # slice deltas partition the parent's mirrored global counters
    assert sum(h.transfer.cpu_to_pim for h in handles) \
        == system.stats.cpu_to_pim
    assert sum(h.transfer.kernel_launches for h in handles) \
        == system.stats.kernel_launches
    # DPU cycle accounting accumulated per gang step
    assert all(h.modeled_seconds > 0 for h in others)
    # every lease was reclaimed
    frag = sched.fragmentation()
    assert frag.free_cores == 16 and frag.n_free_extents == 1


def test_gang_round_robin_interleaves_concurrent_jobs():
    X, y, _ = make_linear_dataset(256, 4, seed=0)
    system = PimSystem(PimConfig(n_cores=8))
    sched = PimScheduler(system, rank_size=4)
    a = sched.submit("linreg", (X, y), version="int32", n_iters=6)
    b = sched.submit("linreg", (X, y), version="int32", n_iters=6)
    sched.step()
    # both fit on the machine, so one turn admits AND advances both
    assert a.state is JobState.RUNNING and b.state is JobState.RUNNING
    assert a.steps == 1 and b.steps == 1
    sched.drain()
    assert a.state is JobState.DONE and b.state is JobState.DONE
    assert np.array_equal(a.result.attributes["coef_"],
                          b.result.attributes["coef_"])


def test_priority_admission_order():
    X, y, _ = make_linear_dataset(128, 4, seed=0)
    system = PimSystem(PimConfig(n_cores=4))     # room for ONE job
    sched = PimScheduler(system, rank_size=4)
    low = sched.submit("linreg", (X, y), version="int32", n_iters=4,
                       priority=0)
    high = sched.submit("linreg", (X, y), version="int32", n_iters=4,
                        priority=5)
    sched.step()
    assert high.state is JobState.RUNNING        # jumped the FIFO head
    assert low.state is JobState.QUEUED
    sched.drain()
    assert low.state is JobState.DONE and high.state is JobState.DONE


def test_cancel_queued_and_running():
    X, y, _ = make_linear_dataset(128, 4, seed=0)
    system = PimSystem(PimConfig(n_cores=4))
    sched = PimScheduler(system, rank_size=4)
    running = sched.submit("linreg", (X, y), version="int32", n_iters=50)
    queued = sched.submit("linreg", (X, y), version="int32", n_iters=50)
    sched.step()
    queued.cancel()
    assert queued.state is JobState.CANCELLED
    running.cancel()
    sched.drain()
    assert running.state is JobState.CANCELLED
    assert running.steps < 50                    # stopped at a boundary
    assert sched.fragmentation().free_cores == 4


def test_unschedulable_job_rejected_at_submit():
    X, y, _ = make_linear_dataset(64, 4, seed=0)
    sched = PimScheduler(PimSystem(PimConfig(n_cores=8)), rank_size=4)
    with pytest.raises(ValueError):
        sched.submit("linreg", (X, y), version="int32", n_cores=12)


def test_custom_workload_default_macro_step():
    """Any registered-protocol workload schedules via the base
    fit_steps default (one macro step)."""

    class OneShot(Workload):
        name = "oneshot"
        versions = ("v0",)
        defaults = {}

        def fit(self, dataset, spec):
            from repro.api import FitResult
            return FitResult(spec, {"n": dataset.n}, {})

    sched = PimScheduler(PimSystem(PimConfig(n_cores=8)), rank_size=4)
    h = sched.submit(OneShot(), np.zeros((16, 2), np.float32))
    sched.drain()
    assert h.state is JobState.DONE
    assert h.steps == 1
    assert h.result.model == {"n": 16}


# ---------------------------------------------------------------------------
# Fusion planning (cheap, fast tier) and fused execution (slow tier).
# ---------------------------------------------------------------------------

def test_fuse_key_eligibility():
    from repro.api import get_workload
    lin = get_workload("linreg")
    kme = get_workload("kmeans")
    s1 = lin.spec("int32", lr=0.1, n_iters=50)
    s2 = lin.spec("int32", lr=0.5, n_iters=50)
    s3 = lin.spec("hyb", lr=0.1, n_iters=50)
    s4 = lin.spec("int32", lr=0.1, n_iters=50, minibatch=8)
    assert fuse_key(lin, s1) == fuse_key(lin, s2)       # lr is lane-local
    assert fuse_key(lin, s1) != fuse_key(lin, s3)       # version differs
    assert fuse_key(lin, s4) is None                    # SGD can't fuse
    assert fuse_key(kme, kme.spec()) is None            # not a GD family
    groups = plan_fusion(lin, [s1, s2, s3, s4])
    assert groups == [[0, 1], [2], [3]]


@pytest.mark.slow
def test_fused_sweep_one_launch_per_step_matches_unfused():
    """Acceptance (c): an 8-point fused GD sweep performs exactly one
    batched kernel launch per step and matches unfused results bit for
    bit."""
    X, y, _ = make_linear_dataset(512, 8, seed=0)
    lrs = [0.02, 0.04, 0.06, 0.08, 0.1, 0.15, 0.2, 0.3]
    n_iters = 25

    system = PimSystem(PimConfig(n_cores=8))
    sched = PimScheduler(system, rank_size=8)
    snap = system.stats.snapshot()
    fused = sched.sweep("linreg", (X, y), {"lr": lrs}, version="int32",
                        n_iters=n_iters, fused=True)
    sched.drain()
    assert all(h.state is JobState.DONE and h.fused for h in fused)
    delta = system.stats.delta(snap)
    # ONE batched launch per step for the whole gang of 8
    assert delta.kernel_launches == n_iters
    assert fused[0].transfer.kernel_launches == n_iters
    # the gang shares one slice and ONE bank-resident dataset
    assert delta.shard_transfers == 2                    # X and y, once

    unfused = sched.sweep("linreg", (X, y), {"lr": lrs}, version="int32",
                          n_iters=n_iters, fused=False)
    sched.drain()
    # 8 independent jobs: 8 launches per step-equivalent, 8 datasets
    assert sum(h.transfer.kernel_launches for h in unfused) \
        == n_iters * len(lrs)
    for hf, hu in zip(fused, unfused):
        assert np.array_equal(hf.result.attributes["coef_"],
                              hu.result.attributes["coef_"])
        assert hf.result.attributes["intercept_"] \
            == hu.result.attributes["intercept_"]


@pytest.mark.slow
def test_fused_sweep_logreg_and_lane_cancel():
    X, y, _ = make_linear_dataset(512, 8, seed=1)
    lrs = [1.0, 2.0, 4.0]
    system = PimSystem(PimConfig(n_cores=8))
    sched = PimScheduler(system, rank_size=8)
    fused = sched.sweep("logreg", (X, y), {"lr": lrs},
                        version="int32_lut_wram", n_iters=20, fused=True)
    sched.step()                        # admit + first gang step
    fused[1].cancel()
    sched.drain()
    assert fused[0].state is JobState.DONE
    assert fused[1].state is JobState.CANCELLED
    assert fused[2].state is JobState.DONE
    ref = sched.sweep("logreg", (X, y), {"lr": [lrs[0]]},
                      version="int32_lut_wram", n_iters=20, fused=False)
    sched.drain()
    assert np.array_equal(fused[0].result.attributes["coef_"],
                          ref[0].result.attributes["coef_"])


@pytest.mark.slow
def test_large_k_mixed_queue_with_backfill():
    """K=16 mixed queue on a fragmented machine drains fully; backfill
    keeps the cores busy when the FIFO head is too big."""
    X, y, _ = make_linear_dataset(256, 4, seed=0)
    Xb, _, _ = make_blobs(256, 4, centers=4, seed=2)
    system = PimSystem(PimConfig(n_cores=16))
    sched = PimScheduler(system, rank_size=4, backfill=True)
    handles = []
    for i in range(16):
        if i % 4 == 3:
            handles.append(sched.submit("kmeans", Xb, n_clusters=4,
                                        max_iter=8, n_cores=8))
        else:
            handles.append(sched.submit(
                "linreg", (X, y), version="int32", n_iters=8,
                n_cores=4, priority=i % 3))
    sched.drain()
    assert all(h.state is JobState.DONE for h in handles)
    frag = sched.fragmentation()
    assert frag.free_cores == 16 and frag.n_free_extents == 1


# ---------------------------------------------------------------------------
# Manifest front end.
# ---------------------------------------------------------------------------

def test_manifest_runs_jobs_and_fused_sweep():
    doc = {
        "system": {"cores": 8, "rank_size": 4},
        "datasets": {
            "lin": {"kind": "linear", "samples": 256, "features": 8,
                    "seed": 0},
            "blobs": {"kind": "blobs", "samples": 256, "features": 4,
                      "centers": 4, "seed": 1},
        },
        "jobs": [
            {"workload": "kmeans", "dataset": "blobs", "cores": 4,
             "params": {"n_clusters": 4, "max_iter": 5}},
        ],
        "sweeps": [
            {"workload": "linreg", "dataset": "lin", "version": "int32",
             "cores": 4, "grid": {"lr": [0.05, 0.1]}, "fused": True,
             "params": {"n_iters": 6}},
        ],
    }
    scheduler, handles = run_manifest(doc)
    assert len(handles) == 3
    assert all(h.state is JobState.DONE for h in handles)
    rows = job_report(handles)
    json.dumps(rows)                       # must be serializable
    assert rows[1]["fused"] and rows[2]["fused"]
    assert scheduler.stats()["jobs"]["done"] == 3


def test_manifest_rejects_unknown_dataset():
    doc = {"system": {"cores": 4},
           "jobs": [{"workload": "linreg", "dataset": "nope"}]}
    with pytest.raises(ValueError, match="unknown dataset"):
        run_manifest(doc)


def test_manifest_file_must_be_a_mapping(tmp_path):
    from repro.sched import load_manifest
    p = tmp_path / "bad.json"
    p.write_text('[{"workload": "linreg"}]')   # valid JSON, wrong shape
    with pytest.raises(ValueError, match="must be a mapping"):
        load_manifest(str(p))


def test_fused_zero_iteration_sweep_accounts_nothing():
    """A fused gang that never launches must not charge steps or DPU
    seconds (parity with the unfused path's accounting)."""
    X, y, _ = make_linear_dataset(128, 4, seed=0)
    sched = PimScheduler(PimSystem(PimConfig(n_cores=8)), rank_size=8)
    hs = sched.sweep("linreg", (X, y), {"lr": [0.1, 0.2]},
                     version="int32", n_iters=0, fused=True)
    sched.drain()
    assert all(h.state is JobState.DONE for h in hs)
    assert all(h.steps == 0 and h.modeled_seconds == 0.0 for h in hs)
    assert hs[0].transfer.kernel_launches == 0
