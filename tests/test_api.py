"""The unified workload-session API (repro/api): registry round-trips,
bank-resident dataset reuse, per-call reduce strategies, and jit-cache
correctness under kernel garbage collection."""
import gc

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (PimConfig, PimDataset, PimEstimator, PimSystem,
                       get_workload, kmeans_sq_distances, list_workloads,
                       make_estimator)
from repro.core.estimators import (PimDecisionTreeClassifier, PimKMeans,
                                   PimLinearRegression,
                                   PimLogisticRegression)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def _pim(n_cores=8):
    return PimSystem(PimConfig(n_cores=n_cores))


# ---------------------------------------------------------------------------
# Workload registry round-trip: every workload x version constructs and
# fits through make_estimator.
# ---------------------------------------------------------------------------

def _tiny_fit(name, version, pim):
    if name == "kmeans":
        X, _, _ = make_blobs(256, 4, centers=4, seed=0)
        est = make_estimator(name, version=version, n_clusters=4,
                             max_iter=10, system=pim).fit(X)
        return est, X, None
    if name == "dtree":
        X, y = make_classification(512, 16, seed=0)
        est = make_estimator(name, version=version, max_depth=3,
                             system=pim).fit(X, y)
        return est, X, y
    X, y, _ = make_linear_dataset(512, 4, seed=0)
    est = make_estimator(name, version=version, n_iters=5,
                         system=pim).fit(X, y)
    return est, X, y


def test_registry_lists_all_workloads():
    assert set(list_workloads()) == {"linreg", "logreg", "dtree", "kmeans",
                                     "emb"}


@pytest.mark.parametrize("name", ["linreg", "logreg", "dtree", "kmeans"])
def test_registry_round_trip_all_versions(name):
    wl = get_workload(name)
    pim = _pim()
    for version in wl.versions:
        est, X, y = _tiny_fit(name, version, pim)
        assert est.result_ is not None
        assert est.result_.workload == name
        assert est.result_.version == version
        pred = est.predict(X)
        assert pred.shape[0] == X.shape[0]
        score = est.score(X) if wl.unsupervised else est.score(X, y)
        assert np.isfinite(score)


def test_workload_aliases_resolve():
    for alias, name in (("lin", "linreg"), ("log", "logreg"),
                        ("dtr", "dtree"), ("kme", "kmeans")):
        assert get_workload(alias) is get_workload(name)


def test_spec_validation():
    wl = get_workload("linreg")
    with pytest.raises(ValueError):
        wl.spec("int64")                        # unknown version
    with pytest.raises(TypeError):
        wl.spec("int32", bogus_hyper=3)         # unknown hyperparameter
    with pytest.raises(TypeError):
        make_estimator("kmeans", k=4)           # native name is n_clusters


def test_get_set_params_protocol():
    est = make_estimator("linreg", version="int32", n_iters=7)
    p = est.get_params()
    assert p["version"] == "int32" and p["n_iters"] == 7
    est.set_params(lr=0.5, version="hyb")
    assert est.get_params()["lr"] == 0.5
    assert est.version == "hyb"
    with pytest.raises(ValueError):
        est.set_params(nonsense=1)


def test_legacy_estimators_delegate_to_registry():
    """The legacy classes are thin shims over the generic facade."""
    for cls, name in ((PimLinearRegression, "linreg"),
                      (PimLogisticRegression, "logreg"),
                      (PimDecisionTreeClassifier, "dtree"),
                      (PimKMeans, "kmeans")):
        est = cls()
        assert isinstance(est, PimEstimator)
        assert est.workload is get_workload(name)


# ---------------------------------------------------------------------------
# Bank-resident dataset reuse (the acceptance criterion): two fits on one
# PimDataset pay for exactly one CPU->PIM shard transfer.
# ---------------------------------------------------------------------------

def test_dataset_reuse_single_shard_transfer():
    pim = _pim()
    X, y, _ = make_linear_dataset(1024, 8, seed=0)
    ds = pim.put(X, y)
    assert pim.stats.shard_transfers == 0     # lazy: nothing moved yet

    make_estimator("linreg", version="int32", n_iters=5, system=pim).fit(ds)
    t1, b1 = pim.stats.shard_transfers, pim.stats.shard_bytes
    assert t1 == 2                            # X and y, one partition each

    # hyperparameter sweep: second fit must add ZERO shard bytes
    make_estimator("linreg", version="int32", n_iters=9, lr=0.3,
                   system=pim).fit(ds)
    assert (pim.stats.shard_transfers, pim.stats.shard_bytes) == (t1, b1)


def test_dataset_view_shared_across_workloads():
    """LOG reuses LIN's data view (same precision ladder)."""
    pim = _pim()
    X, y, _ = make_linear_dataset(512, 4, seed=1)
    ds = pim.put(X, y)
    make_estimator("linreg", version="int32", n_iters=3, system=pim).fit(ds)
    t1 = pim.stats.shard_transfers
    make_estimator("logreg", version="int32_lut_wram", n_iters=3,
                   system=pim).fit(ds)
    assert pim.stats.shard_transfers == t1


def test_dataset_versions_materialize_distinct_views():
    pim = _pim()
    X, y, _ = make_linear_dataset(512, 4, seed=2)
    ds = pim.put(X, y)
    ds.gd_view("fp32")
    t_fp32 = pim.stats.shard_transfers
    ds.gd_view("int32")
    assert pim.stats.shard_transfers > t_fp32   # new precision, new view
    t_int32 = pim.stats.shard_transfers
    ds.gd_view("hyb")
    ds.gd_view("bui")                           # same datatypes as hyb
    assert pim.stats.shard_transfers == t_int32 + 2


def test_kmeans_restarts_share_one_transfer():
    pim = _pim()
    X, _, _ = make_blobs(512, 4, centers=4, seed=0)
    ds = pim.put(X)
    make_estimator("kmeans", n_clusters=4, n_init=3, max_iter=10,
                   system=pim).fit(ds)
    assert pim.stats.shard_transfers == 1


def test_estimator_accepts_dataset_or_arrays():
    pim = _pim()
    X, y, _ = make_linear_dataset(256, 4, seed=3)
    e1 = make_estimator("linreg", n_iters=10, system=pim).fit(X, y)
    e2 = make_estimator("linreg", n_iters=10, system=pim).fit(pim.put(X, y))
    np.testing.assert_array_equal(e1.coef_, e2.coef_)


# ---------------------------------------------------------------------------
# jit-cache correctness: the old id(fn)-keyed cache could serve a stale
# compiled kernel when a collected function's id was recycled.
# ---------------------------------------------------------------------------

def test_jit_cache_correct_under_kernel_gc():
    pim = _pim(4)
    x = np.arange(16, dtype=np.float32)
    xs = pim.shard_rows(x)
    for c in range(24):
        def kern(xc, _unused, _c=float(c)):
            return {"s": jnp.sum(xc) * _c}
        out = pim.map_reduce(kern, (xs,), (0,))
        del kern
        gc.collect()   # invite id reuse for the next closure
        assert float(out["s"]) == pytest.approx(x.sum() * c), c


def test_named_kernel_reregistration_not_stale():
    pim = _pim(4)
    xs = pim.shard_rows(np.arange(8, dtype=np.float32))
    pim.register_kernel("k", lambda xc, _: {"s": jnp.sum(xc)})
    a = float(pim.map_reduce("k", (xs,), (0,))["s"])
    pim.register_kernel("k", lambda xc, _: {"s": 2 * jnp.sum(xc)})
    b = float(pim.map_reduce("k", (xs,), (0,))["s"])
    assert b == pytest.approx(2 * a)


def test_named_kernel_builder_runs_once():
    pim = _pim(4)
    calls = []
    for _ in range(3):
        pim.named_kernel("only.once", lambda: calls.append(1) or (
            lambda xc, _: {"s": jnp.sum(xc)}))
    assert len(calls) == 1


def test_unknown_kernel_name_raises():
    pim = _pim(4)
    with pytest.raises(KeyError):
        pim.map_reduce("never.registered", (jnp.zeros((4, 1)),), (0,))


# ---------------------------------------------------------------------------
# Reduce strategies: selectable per call, numerically consistent.
# ---------------------------------------------------------------------------

def test_reduce_strategies_agree():
    x = np.random.RandomState(0).randint(-50, 50, 64).astype(np.int32)
    pim = _pim(8)
    xs = pim.shard_rows(x)

    def kern(xc, _):
        return {"s": jnp.sum(xc)}

    outs = {s: int(pim.map_reduce(kern, (xs,), (0,), strategy=s)["s"])
            for s in ("fabric", "host", "hierarchical")}
    assert outs["fabric"] == outs["host"] == outs["hierarchical"] == x.sum()


def test_hierarchical_reduce_counts_intercore_bytes():
    pim = _pim(8)
    xs = pim.shard_rows(np.ones(32, np.float32))
    pim.map_reduce(lambda xc, _: {"s": jnp.sum(xc)}, (xs,), (0,),
                   strategy="hierarchical")
    assert pim.stats.inter_core_via_host > 0


# ---------------------------------------------------------------------------
# K-Means scoring goes through the single shared distance helper.
# ---------------------------------------------------------------------------

def test_kmeans_distances_are_true_distances():
    rng = np.random.RandomState(0)
    X = rng.normal(size=(50, 6)).astype(np.float32)
    C = rng.normal(size=(4, 6)).astype(np.float32)
    d = kmeans_sq_distances(X, C)
    ref = ((X[:, None, :] - C[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(d, ref, atol=1e-3)
    assert (d > -1e-3).all()   # a dropped ||x||^2 term would go negative


def test_kmeans_score_and_predict_consistent():
    X, _, _ = make_blobs(600, 6, centers=4, seed=5)
    km = make_estimator("kmeans", n_clusters=4, seed=0, max_iter=20).fit(X)
    pred = km.predict(X)
    np.testing.assert_array_equal(pred, km.labels_)
    d = kmeans_sq_distances(X, km.cluster_centers_)
    assert km.score(X) == pytest.approx(-float(d.min(1).sum()), rel=1e-6)


# ---------------------------------------------------------------------------
# Dataset handle basics.
# ---------------------------------------------------------------------------

def test_put_returns_dataset_handle():
    pim = _pim()
    X, y, _ = make_linear_dataset(100, 3, seed=0)
    ds = pim.put(X, y)
    assert isinstance(ds, PimDataset)
    assert (ds.n, ds.n_features) == (100, 3)


def test_gd_view_requires_targets():
    pim = _pim()
    ds = pim.put(np.zeros((10, 2), np.float32))
    with pytest.raises(ValueError):
        ds.gd_view("fp32")
