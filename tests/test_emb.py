"""EMB workload family: sparse gather/scatter kernels, ShardedTable
placement, deferred-update training identities, compressed flushes, and
the spool-lane / replay serve satellites (DESIGN.md §15).

The load-bearing claims:

  * ``emb_scatter_add`` is duplicate-safe and bit-exact across backends
    (segment-sum formulation — same reduction order in ref and Pallas);
  * deferred updates with D=1 are BIT-identical to eager (both dtypes);
  * the fused (lax.scan) engine matches the serial loop bit-for-bit;
  * a mid-window preemption resumes bit-identically on another width;
  * deferred windows shrink ``flush_bytes`` on Zipf-skewed traffic.
"""
import json
import os

import numpy as np
import pytest

from repro.api import make_estimator
from repro.api.table import ShardedTable
from repro.data.synthetic import make_recsys
from repro.emb import EmbConfig, fit, fit_steps
from repro.kernels.pallas_compat import HAS_PALLAS
from repro.kernels.sparse_gather import (IDX_PAD, ROW_PAD_ID, emb_gather,
                                         emb_scatter_add)
from repro.kernels.sparse_gather.ref import (emb_gather_ref,
                                             emb_scatter_add_ref)
from repro.systems import make_system, run_steps

slow = pytest.mark.slow


def _table(r=22, d=3, vmax=40, dtype=np.int32, seed=0):
    """A shard-like table block: rows + a sparse id map with pads."""
    rng = np.random.RandomState(seed)
    ids = rng.choice(vmax, size=r - 2, replace=False).astype(np.int32)
    ids = np.concatenate(   # two padded slots at the tail
        [ids, np.array([ROW_PAD_ID, ROW_PAD_ID], np.int32)])
    rng.shuffle(ids)
    if dtype == np.int32:
        tab = rng.randint(-500, 500, size=(r, d)).astype(np.int32)
    else:
        tab = rng.randn(r, d).astype(np.float32)
    tab[ids == ROW_PAD_ID] = 0
    return tab, ids


# ---------------------------------------------------------------------------
# Kernel semantics vs a plain numpy oracle (backend-independent).
# ---------------------------------------------------------------------------

class TestSparseGatherSemantics:
    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_gather_matches_numpy(self, dtype):
        tab, ids = _table(dtype=dtype)
        rng = np.random.RandomState(1)
        owned = ids[ids >= 0]
        idx = rng.choice(owned, size=17).astype(np.int32)
        out = np.asarray(emb_gather_ref(tab, ids, idx))
        slot = {int(v): s for s, v in enumerate(ids) if v >= 0}
        want = np.stack([tab[slot[int(v)]] for v in idx])
        np.testing.assert_array_equal(out, want)

    def test_gather_miss_returns_zeros(self):
        # ids this shard does NOT own gather zero rows — the cross-shard
        # fabric sum then reconstructs the full row from the owner
        tab, ids = _table()
        missing = np.array([v for v in range(40)
                            if v not in set(ids.tolist())][:5], np.int32)
        out = np.asarray(emb_gather_ref(tab, ids, missing))
        np.testing.assert_array_equal(out, 0)

    def test_idx_pad_never_matches_row_pad(self):
        # padded batch slots (IDX_PAD) must not match padded table
        # slots (ROW_PAD_ID) — distinct sentinels by construction
        assert IDX_PAD != ROW_PAD_ID
        tab, ids = _table()
        idx = np.full(4, IDX_PAD, np.int32)
        np.testing.assert_array_equal(
            np.asarray(emb_gather_ref(tab, ids, idx)), 0)

    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_scatter_add_duplicates(self, dtype):
        # ALL batch slots hit the same row: the segment-sum must add
        # every contribution (the classic scatter-add razor)
        tab, ids = _table(dtype=dtype)
        v = int(ids[ids >= 0][3])
        idx = np.full(9, v, np.int32)
        upd = (np.arange(9 * 3).reshape(9, 3) + 1).astype(dtype)
        out = np.asarray(emb_scatter_add_ref(tab, ids, idx, upd))
        want = tab.copy()
        want[np.nonzero(ids == v)[0][0]] += upd.sum(0).astype(dtype)
        np.testing.assert_array_equal(out, want)

    def test_scatter_add_empty_batch(self):
        tab, ids = _table()
        out = np.asarray(emb_scatter_add(
            tab, ids, np.zeros(0, np.int32), np.zeros((0, 3), np.int32),
            backend="jnp_ref"))
        np.testing.assert_array_equal(out, tab)

    def test_gather_empty_batch(self):
        tab, ids = _table()
        out = np.asarray(emb_gather(tab, ids, np.zeros(0, np.int32),
                                    backend="jnp_ref"))
        assert out.shape == (0, 3)


# ---------------------------------------------------------------------------
# Pallas parity: interpret-mode kernels vs the jnp_ref oracle, bit-exact.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not HAS_PALLAS,
                    reason="no Pallas in this jax build "
                           "(dispatch degrades to jnp_ref)")
class TestSparseGatherParity:
    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    @pytest.mark.parametrize("b", [1, 8, 20])   # 20 forces a ragged tail
    def test_gather_parity(self, dtype, b):
        tab, ids = _table(dtype=dtype)
        rng = np.random.RandomState(2)
        idx = rng.choice(ids[ids >= 0], size=b).astype(np.int32)
        ref = np.asarray(emb_gather(tab, ids, idx, backend="jnp_ref"))
        pal = np.asarray(emb_gather(tab, ids, idx,
                                    backend="pallas_interpret", block_b=8))
        np.testing.assert_array_equal(ref, pal)

    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_scatter_parity_with_duplicates(self, dtype):
        tab, ids = _table(r=22, dtype=dtype)  # 22 pads up to block_r=8
        rng = np.random.RandomState(3)
        idx = rng.choice(ids[ids >= 0], size=30).astype(np.int32)
        idx[:7] = idx[0]                      # heavy duplication
        if dtype == np.int32:
            upd = rng.randint(-9, 9, size=(30, 3)).astype(np.int32)
        else:
            upd = rng.randn(30, 3).astype(np.float32)
        ref = np.asarray(emb_scatter_add(tab, ids, idx, upd,
                                         backend="jnp_ref"))
        pal = np.asarray(emb_scatter_add(tab, ids, idx, upd,
                                         backend="pallas_interpret",
                                         block_r=8))
        np.testing.assert_array_equal(ref, pal)

    def test_cross_shard_straddle(self):
        # one flush batch touching rows owned by DIFFERENT shards:
        # per-shard scatters each absorb only their own rows, and
        # reassembly equals a global numpy scatter
        pim = make_system("pim", n_cores=4)
        V, D = 23, 3
        W = np.random.RandomState(4).randn(V, D).astype(np.float32)
        table = pim.put_table(W, placement="mod")
        shards, ids = table.view("fp32")
        idx = np.array([0, 1, 2, 3, 5, 5, 22], np.int32)  # 4 shards hit
        upd = np.arange(7 * D, dtype=np.float32).reshape(7, D)
        out = np.stack([
            np.asarray(emb_scatter_add(
                np.asarray(shards)[s], table.ids[s], idx, upd,
                backend="pallas_interpret", block_r=4))
            for s in range(4)])
        got = table.unshard(out)
        want = W.copy()
        np.add.at(want, idx, upd)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# ShardedTable: placement, round-trips, the staging ledger.
# ---------------------------------------------------------------------------

class TestShardedTable:
    @pytest.mark.parametrize("placement", ["mod", "hash"])
    def test_placement_round_trip(self, placement):
        pim = make_system("pim", n_cores=4)
        W = np.arange(22 * 3, dtype=np.float32).reshape(22, 3)
        t = pim.put_table(W, placement=placement, seed=7)
        shards, _ids = t.view("fp32")
        np.testing.assert_array_equal(t.unshard(np.asarray(shards)), W)

    def test_mod_placement_round_robin(self):
        pim = make_system("pim", n_cores=4)
        t = pim.put_table(np.zeros((22, 3), np.float32))
        assert t.lookup_shard(0) == (0, 0)
        assert t.lookup_shard(5) == (1, 1)   # 5 % 4, 5 // 4
        # every real row owned exactly once
        owned = t.ids[t.ids >= 0]
        assert sorted(owned.tolist()) == list(range(22))

    def test_int32_view_dtype_and_stats(self):
        pim = make_system("pim", n_cores=4)
        t = pim.put_table(np.random.RandomState(0).randn(22, 3))
        shards, _ = t.view("int32", frac_bits=10)
        assert np.asarray(shards).dtype == np.int32
        assert t.n_views == 1
        assert all(st["bytes"] > 0 for st in t.shard_stats)
        assert sum(st["rows"] for st in t.shard_stats) == 22

    def test_ledger_dedup_sums_duplicates(self):
        pim = make_system("pim", n_cores=2)
        t = pim.put_table(np.zeros((8, 2), np.float32))
        t.stage([1, 1, 3], np.ones((3, 2), np.int32))
        t.stage([3, 5], 2 * np.ones((2, 2), np.int32))
        assert t.pending_batches == 2 and t.pending_rows == 5
        idx, upd = t.drain(dedup=True)
        np.testing.assert_array_equal(idx, [1, 3, 5])
        np.testing.assert_array_equal(upd, [[2, 2], [3, 3], [2, 2]])
        assert t.pending_batches == 0

    def test_drain_no_dedup_is_verbatim(self):
        pim = make_system("pim", n_cores=2)
        t = pim.put_table(np.zeros((8, 2), np.float32))
        t.stage([1, 1], np.ones((2, 2), np.float32))
        idx, upd = t.drain(dedup=False)
        np.testing.assert_array_equal(idx, [1, 1])
        assert upd.shape == (2, 2)


def _recsys(n=768, nu=48, ni=36, d=4, seed=3):
    return make_recsys(n, nu, ni, dim=d, seed=seed)


def _cfg(**kw):
    base = dict(version="int32", n_iters=24, batch=32, dim=4, lr=1.0,
                frac_bits=12, seed=1)
    base.update(kw)
    return EmbConfig(**base)


def _fit_raw(cfg, X, y, cores=8, kind="pim"):
    system = make_system(kind, n_cores=cores)
    res = fit(system.put(X, y), cfg)
    return res, system


# ---------------------------------------------------------------------------
# Trainer identities (the §15.3 deferred-update contract).
# ---------------------------------------------------------------------------

class TestEmbTrainer:
    def test_eager_learns_both_versions(self):
        X, y = _recsys()
        for ver in ("fp32", "int32"):
            res, _ = _fit_raw(_cfg(version=ver, n_iters=40,
                                   record_every=20), X, y)
            first, last = res.history[0][1], res.history[-1][1]
            assert last < first, (ver, res.history)

    @pytest.mark.parametrize("ver", ["int32", "fp32"])
    def test_deferred_d1_bit_identical_to_eager(self, ver):
        X, y = _recsys()
        eager, se = _fit_raw(_cfg(version=ver, deferred=False), X, y)
        lazy, sl = _fit_raw(_cfg(version=ver, flush_every=1,
                                 deferred=True), X, y)
        np.testing.assert_array_equal(eager.user_raw, lazy.user_raw)
        np.testing.assert_array_equal(eager.item_raw, lazy.item_raw)
        # same logical sparse payload shipped, window or no window
        assert se.stats.flush_bytes == sl.stats.flush_bytes

    @pytest.mark.parametrize("ver", ["int32", "fp32"])
    def test_fused_bit_identical_to_serial(self, ver):
        X, y = _recsys()
        a, sa = _fit_raw(_cfg(version=ver, flush_every=6, fuse_steps=1,
                              record_every=6), X, y)
        b, sb = _fit_raw(_cfg(version=ver, flush_every=6, fuse_steps=4,
                              record_every=6), X, y)
        np.testing.assert_array_equal(a.user_raw, b.user_raw)
        np.testing.assert_array_equal(a.item_raw, b.item_raw)
        assert a.history == b.history
        assert sa.stats.flush_bytes == sb.stats.flush_bytes
        # fusion collapses launches: serial pays ~1/step + 1/flush
        assert (sb.stats.kernel_launches
                < sa.stats.kernel_launches)

    def test_host_matches_pim_bitwise(self):
        # shard-local gathers contribute zeros off-owner, so the fabric
        # sum is EXACT even in fp32 — one resident image (host) and 8
        # shards (pim) must agree bit for bit
        X, y = _recsys()
        for ver in ("fp32", "int32"):
            a, _ = _fit_raw(_cfg(version=ver, flush_every=3), X, y,
                            kind="pim")
            b, _ = _fit_raw(_cfg(version=ver, flush_every=3), X, y,
                            kind="host", cores=8)
            np.testing.assert_array_equal(a.user_raw, b.user_raw)
            np.testing.assert_array_equal(a.item_raw, b.item_raw)

    def test_deferred_window_cuts_flush_traffic(self):
        # Zipf-skewed ids: hot rows repeat within a window, dedup ships
        # them once — the LazyDP traffic saving, on flush_bytes
        X, y = make_recsys(2048, 64, 48, dim=4, zipf_a=1.1, seed=0)
        byD = {}
        for D in (1, 8):
            _, s = _fit_raw(_cfg(n_iters=32, batch=128,
                                 flush_every=D), X, y)
            byD[D] = s.stats.flush_bytes
        assert byD[1] / byD[8] >= 2.0, byD

    def test_resume_mid_window_bit_identical(self):
        X, y = _recsys()
        cfg = _cfg(flush_every=4, record_every=8)
        ref, _ = _fit_raw(cfg, X, y)
        gen = fit_steps(make_system("pim", n_cores=8).put(X, y), cfg)
        done, snap = 0, None
        while snap is None:
            tick = next(gen)
            done += int(tick)
            if done >= 10:          # 10 % 4 == 2 -> ledger non-empty
                snap = tick.snapshot()
        assert snap["arrays"]["pend_u_idx"].size > 0
        res = run_steps(fit_steps(
            make_system("pim", n_cores=4).put(X, y), cfg, state=snap))
        np.testing.assert_array_equal(ref.user_raw, res.user_raw)
        np.testing.assert_array_equal(ref.item_raw, res.item_raw)
        assert ref.history == res.history

    def test_compressed_flush_accounting(self):
        X, y = _recsys()
        _, s = _fit_raw(_cfg(flush_every=4, compress_flush=True), X, y)
        # int8 rows + f32 scales on the wire, less than the raw payload
        assert 0 < s.stats.compressed_bytes < s.stats.flush_bytes

    def test_padded_vocab_tail(self):
        # vocab not divisible by shard count: pad slots must stay inert
        X, y = make_recsys(512, 13, 11, dim=4, seed=5)  # 13 % 8 != 0
        res, _ = _fit_raw(_cfg(n_iters=16), X, y)
        assert res.user_emb.shape == (13, 4)
        assert res.item_emb.shape == (11, 4)


# ---------------------------------------------------------------------------
# Registry / estimator / scheduler integration.
# ---------------------------------------------------------------------------

class TestEmbIntegration:
    def test_estimator_round_trip(self):
        X, y = make_recsys(2048, 128, 96, dim=4, seed=0)
        est = make_estimator("emb", version="int32", n_iters=60,
                             batch=64, dim=4, lr=1.0, frac_bits=12,
                             flush_every=4, seed=1)
        est.fit(make_system("pim", n_cores=8).put(X, y))
        assert est.score(X, y) > 0.4
        assert est.predict(X[:5]).shape == (5,)

    def test_manifest_recsys_job_with_cost_model(self):
        from repro.sched.manifest import job_report, run_manifest
        doc = {"system": {"kind": "pim", "cores": 8},
               "datasets": {"clicks": {"kind": "recsys", "samples": 1024,
                                       "n_users": 64, "n_items": 48,
                                       "dim": 4, "seed": 0}},
               "jobs": [{"workload": "emb", "version": "int32",
                         "dataset": "clicks", "name": "emb-j",
                         "params": {"n_iters": 16, "batch": 32, "dim": 4,
                                    "lr": 1.0, "frac_bits": 12,
                                    "flush_every": 4}}]}
        _sched, handles = run_manifest(doc)
        row = job_report(handles)[0]
        assert row["state"] == "done" and row["iters"] == 16
        # _COST_KEYS routes emb into the hierarchical model
        assert row["modeled_dpu_seconds"] > 0


# ---------------------------------------------------------------------------
# Serve satellites: spool priority lane + sidecar replay on restart.
# ---------------------------------------------------------------------------

def _spool_manifest(spool, name, prio=None):
    doc = {"datasets": {"d": {"kind": "linear", "samples": 256,
                              "features": 4}},
           "jobs": [{"workload": "linreg", "version": "fp32",
                     "name": name, "params": {"n_iters": 4}}]}
    if prio is not None:
        doc["priority"] = prio
    with open(os.path.join(spool, name + ".json"), "w") as fh:
        json.dump(doc, fh)


class TestServeSatellites:
    def test_priority_lane_orders_scan(self, tmp_path):
        from repro.sched.manifest import serve_manifests
        from repro.sched.scheduler import PimScheduler
        spool = str(tmp_path)
        _spool_manifest(spool, "aaa")            # default priority 0
        _spool_manifest(spool, "bbb", prio=5)    # jumps the name order
        _spool_manifest(spool, "ccc", prio=5)    # tie -> name order
        sched = PimScheduler(make_system("host", n_cores=2))
        try:
            recs = serve_manifests(sched, spool, poll_interval=0.05,
                                   idle_timeout=0.4)
        finally:
            sched.shutdown()
        order = [os.path.basename(r["path"]) for r in recs]
        assert order == ["bbb.json", "ccc.json", "aaa.json"]
        assert all(r["state"] == "accepted" for r in recs)

    def test_restarted_serve_replays_sidecars(self, tmp_path):
        # kill/restart: the second watcher must replay the durable
        # verdicts (sidecars) instead of re-admitting the manifests
        from repro.sched.manifest import serve_manifests
        from repro.sched.scheduler import PimScheduler
        spool = str(tmp_path)
        _spool_manifest(spool, "job1")
        _spool_manifest(spool, "job2", prio=3)
        s1 = PimScheduler(make_system("host", n_cores=2))
        try:
            first = serve_manifests(s1, spool, poll_interval=0.05,
                                    idle_timeout=0.4)
        finally:
            s1.shutdown()     # "kill" the service
        assert len(first) == 2
        s2 = PimScheduler(make_system("host", n_cores=2))
        try:
            second = serve_manifests(s2, spool, poll_interval=0.05,
                                     idle_timeout=0.4)
        finally:
            s2.shutdown()
        assert len(second) == 2
        assert all(r.get("replayed") for r in second)
        assert all(r["state"] == "accepted" for r in second)


# ---------------------------------------------------------------------------
# CompressedReduce as a general ReduceStrategy (satellite a).
# ---------------------------------------------------------------------------

class TestCompressedReduce:
    def test_float_reduce_approximates_exact(self):
        import jax.numpy as jnp
        from repro.systems.compress import CompressedReduce
        pim = make_system("pim", n_cores=4)
        Xs = pim.shard_rows(np.arange(64, dtype=np.float32).reshape(32, 2))
        k = pim.named_kernel("t.colsum", lambda: (
            lambda xs: {"s": jnp.sum(xs, axis=0)}))
        out = pim.map_reduce(k, (Xs,), (), strategy=CompressedReduce())
        exact = pim.map_reduce(k, (Xs,), ())
        np.testing.assert_allclose(np.asarray(out["s"], np.float64),
                                   np.asarray(exact["s"], np.float64),
                                   rtol=0.05)
        assert pim.stats.compressed_bytes > 0

    def test_integer_leaves_pass_exact(self):
        # Q-format integer trees must NOT quantize — bit-exactness is
        # the whole point of the int32 ladder
        import jax.numpy as jnp
        from repro.systems.compress import CompressedReduce
        pim = make_system("pim", n_cores=4)
        Xs = pim.shard_rows(
            np.random.RandomState(0).randint(-99, 99, (32, 3)).astype(
                np.int32))
        k = pim.named_kernel("t.icolsum", lambda: (
            lambda xs: {"s": jnp.sum(xs, axis=0)}))
        out = pim.map_reduce(k, (Xs,), (), strategy=CompressedReduce())
        exact = pim.map_reduce(k, (Xs,), ())
        np.testing.assert_array_equal(np.asarray(out["s"]),
                                      np.asarray(exact["s"]))

    def test_error_feedback_bounds_cumulative_error(self):
        # EF's contract is about the SUM of repeated reduces: the
        # residual re-injects, so cumulative error stays bounded by
        # ~one quantization step, while stateless compression repeats
        # the same bias every round and accumulates it linearly
        import jax.numpy as jnp
        from repro.systems.compress import CompressedReduce
        pim = make_system("pim", n_cores=4)
        rows = np.random.RandomState(1).randn(32, 4).astype(np.float32)
        Xs = pim.shard_rows(rows)
        k = pim.named_kernel("t.colsum2", lambda: (
            lambda xs: {"s": jnp.sum(xs, axis=0)}))
        exact = rows.sum(0, dtype=np.float64)
        rounds = 6

        def cumulative_err(make_strategy):
            acc = np.zeros(4, np.float64)
            for _ in range(rounds):
                out = pim.map_reduce(k, (Xs,), (),
                                     strategy=make_strategy())
                acc += np.asarray(out["s"], np.float64)
            return float(np.abs(acc - rounds * exact).max())

        persistent = CompressedReduce()      # EF buffers carry over
        with_ef = cumulative_err(lambda: persistent)
        without_ef = cumulative_err(CompressedReduce)  # fresh each time
        assert without_ef > 0                # quantization does bias
        assert with_ef < without_ef


# ---------------------------------------------------------------------------
# Slow tier: the three-system compare driver + the bench-scale claim.
# ---------------------------------------------------------------------------

@slow
class TestEmbCompareSlow:
    def test_compare_tiny_includes_emb_on_three_systems(self):
        from repro.launch.compare import run_compare
        record = run_compare(tiny=True, cores=8)
        emb_rows = [r for r in record["rows"] if r["workload"] == "emb"]
        assert {r["system"] for r in emb_rows} == {"pim", "host",
                                                   "gpu-model"}
        for r in emb_rows:
            assert r["modeled_s"] > 0
        pim_row = next(r for r in emb_rows if r["system"] == "pim")
        assert pim_row["version"] == "int32"
        assert pim_row["modeled_kernel_s"] > 0

    def test_deferred_equal_loss_half_traffic(self):
        # the PR's acceptance claim at bench scale: D=8 cuts the sparse
        # update traffic >= 2x while landing within 1% of eager's
        # final training loss
        X, y = make_recsys(8192, 256, 192, dim=8, zipf_a=1.2, seed=0)
        out = {}
        for D in (1, 8):
            cfg = EmbConfig(version="int32", n_iters=192, batch=256,
                            dim=8, lr=1.0, frac_bits=12, seed=1,
                            flush_every=D, record_every=192)
            system = make_system("pim", n_cores=16)
            res = fit(system.put(X, y), cfg)
            out[D] = (system.stats.flush_bytes, res.history[-1][1])
        (eager_bytes, eager_loss), (lazy_bytes, lazy_loss) = out[1], out[8]
        assert eager_bytes / lazy_bytes >= 2.0, out
        assert abs(lazy_loss - eager_loss) <= 0.01 * eager_loss + 1e-9, out
