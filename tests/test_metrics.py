import numpy as np

from repro.core.metrics import (accuracy, adjusted_rand_index,
                                calinski_harabasz, frobenius_shift,
                                training_error_rate)


def test_training_error_rate():
    pred = np.array([0.9, 0.1, 0.6, 0.4])
    y = np.array([1.0, 0.0, 0.0, 1.0])
    assert training_error_rate(pred, y) == 50.0


def test_accuracy():
    assert accuracy(np.array([1, 0, 1]), np.array([1, 1, 1])) == 2 / 3


def test_ari_identical_partitions():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(a, a) == 1.0
    # relabeling-invariant
    b = np.array([5, 5, 9, 9, 7, 7])
    assert adjusted_rand_index(a, b) == 1.0


def test_ari_random_partitions_near_zero():
    rng = np.random.RandomState(0)
    a = rng.randint(0, 4, 4000)
    b = rng.randint(0, 4, 4000)
    assert abs(adjusted_rand_index(a, b)) < 0.02


def test_calinski_harabasz_prefers_true_clustering():
    rng = np.random.RandomState(1)
    centers = np.array([[0, 0], [10, 10], [-10, 10]], np.float64)
    y = rng.randint(0, 3, 3000)
    X = centers[y] + rng.normal(0, 1, (3000, 2))
    good = calinski_harabasz(X, y)
    bad = calinski_harabasz(X, rng.randint(0, 3, 3000))
    assert good > 100 * max(bad, 1e-9)


def test_frobenius_shift():
    a = np.eye(3)
    assert frobenius_shift(a, a) == 0.0
    assert frobenius_shift(a, 2 * a) > 0.5
