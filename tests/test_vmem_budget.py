"""Hardware-codesign checks: each Pallas kernel's per-grid-step VMEM
working set (blocks + scratch) must fit comfortably in TPU VMEM.

Budget: 16 MiB — conservative for v5e-class cores (real VMEM is larger,
but staying far under leaves room for double buffering, which the Pallas
pipeline emitter inserts automatically).  These are *static* checks on
the BlockSpec arithmetic — the structural analogue of the paper's WRAM
budget argument (the 40 KB LUT in a 64 KB scratchpad, Fig. 4).
"""
VMEM_BUDGET = 16 * 2 ** 20
DBL = 2  # double buffering factor on streamed blocks


def test_quant_matmul_vmem():
    bm = bn = bk = 128
    working = DBL * (bm * bk * 1 + bk * bn * 1)   # int8 in-blocks
    working += bm * bn * 4 * 2                    # int32 out + scratch acc
    assert working < VMEM_BUDGET
    assert working < 512 * 2 ** 10                # actually tiny: < 512 KiB


def test_flash_attention_vmem():
    bq = bk = 128
    d = 256                                       # generous head dim
    working = DBL * (bq * d + 2 * bk * d) * 2     # bf16 q/k/v blocks
    working += (bq * d + 2 * bq) * 4              # f32 acc + m + l scratch
    working += bq * d * 2                         # out block
    assert working < VMEM_BUDGET


def test_kmeans_assign_vmem():
    bn, f, k = 1024, 64, 64                       # generous upper bounds
    working = DBL * bn * f * 2                    # int16 point block
    working += k * f * 2                          # pinned centroids
    working += (k * f + k + bn) * 4               # int32 sums/counts/labels
    assert working < VMEM_BUDGET


def test_gini_split_vmem():
    bn, f, L, C = 1024, 32, 64, 4
    working = DBL * (bn * f * 4 + bn * 8)         # f32 block + 2 int vecs
    working += L * f * 4                          # pinned thresholds
    working += (L * C * f + L * C) * 4            # count accumulators
    assert working < VMEM_BUDGET


def test_lut_sigmoid_vmem():
    """The paper's own budget argument: the 40 KB sigmoid table plus a
    streamed activation block fits any scratchpad tier."""
    table = 20 * 1024 * 2                         # = paper's 40 KB LUT
    block = DBL * 256 * 128 * 4                   # int32 activation tile
    assert table + block + 256 * 128 * 4 < VMEM_BUDGET
    assert table == 40 * 1024
