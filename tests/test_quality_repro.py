"""Training-quality reproduction of paper §4.1 / §5.1.

The paper's quality claims (synthetic datasets, single PIM core semantics):
  LIN: FP32 error == CPU; INT32/HYB within ~1 pt of FP32 (Fig. 6)
  LOG: FP32 == CPU; LUT versions <= Taylor-INT32 error (Fig. 7)
  DTR: PIM accuracy ~~ CPU accuracy (0.90008 vs 0.90175)
  KME: ARI(PIM, CPU) ~ 0.999; equal Calinski-Harabasz scores (§5.1.4)

Exact error *values* depend on the (unpublished) synthetic data draw, so
these tests assert the paper's *relationships* with tolerance bands, and
benchmarks/fig06_07_quality.py reports the actual curves next to the
paper's numbers.
"""
import numpy as np
import pytest

from repro.core import dtree, kmeans, linreg, logreg

# full quality reproduction: 600-iteration trainings over every version —
# minutes of wall time, excluded from the fast tier (scripts/ci.sh)
pytestmark = pytest.mark.slow
from repro.core.metrics import (accuracy, adjusted_rand_index,
                                calinski_harabasz, training_error_rate)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)
from repro.systems import PimConfig, PimSystem, make_system

N_ITERS = 600


@pytest.fixture(scope="module")
def linlog_data():
    # paper §4.1: 8192 samples, 16 attributes, 4 decimal digits
    return make_linear_dataset(8192, 16, decimals=4, seed=0)


@pytest.fixture(scope="module")
def pim():
    return PimSystem(PimConfig(n_cores=16))


@pytest.fixture(scope="module")
def host():
    """The processor-centric CPU baseline: the same workloads on a
    HostSystem (fp32, exact transcendentals) — DESIGN.md §10.3."""
    return make_system("host")


class TestLinQuality:
    @pytest.fixture(scope="class")
    def errors(self, linlog_data, pim, host):
        X, y, _ = linlog_data
        out = {}
        cpu = linreg.fit(host.put(X, y),
                         linreg.GdConfig(version="fp32", n_iters=N_ITERS))
        out["cpu"] = training_error_rate(cpu.predict(X), y)
        ds = pim.put(X, y)
        for ver in linreg.VERSIONS:
            r = linreg.fit(ds,
                           linreg.GdConfig(version=ver, n_iters=N_ITERS))
            out[ver] = training_error_rate(r.predict(X), y)
        return out

    def test_fp32_matches_cpu(self, errors):
        """Paper: 'LIN-FP32 ... same as the CPU version'."""
        assert errors["fp32"] == pytest.approx(errors["cpu"], abs=0.05)

    def test_all_versions_converge(self, errors):
        for ver in linreg.VERSIONS:
            assert errors[ver] < 5.0, (ver, errors)

    def test_integer_versions_close_to_fp32(self, errors):
        """Paper Fig. 6: integer-version error stays within ~1 pt."""
        assert abs(errors["int32"] - errors["fp32"]) < 1.0
        assert abs(errors["hyb"] - errors["fp32"]) < 1.5

    def test_hyb_and_bui_identical(self, errors):
        """Paper: same datatypes -> same behavior."""
        assert errors["hyb"] == errors["bui"]


class TestLogQuality:
    @pytest.fixture(scope="class")
    def errors(self, linlog_data, pim, host):
        X, y, _ = linlog_data
        out = {}
        # fp32 on the host target selects the exact sigmoid (the
        # paper's MKL baseline), not the DPU Taylor expansion
        cpu = logreg.fit(
            host.put(X, y),
            logreg.LogRegConfig(version="fp32", n_iters=N_ITERS))
        out["cpu"] = training_error_rate(cpu.predict(X), y, threshold=0.0)
        ds = pim.put(X, y)
        for ver in logreg.VERSIONS:
            r = logreg.fit(
                ds, logreg.LogRegConfig(version=ver, n_iters=N_ITERS))
            out[ver] = training_error_rate(r.predict(X), y, threshold=0.0)
        return out

    def test_fp32_matches_cpu(self, errors):
        assert errors["fp32"] == pytest.approx(errors["cpu"], abs=0.3)

    def test_all_versions_converge(self, errors):
        for ver in logreg.VERSIONS:
            assert errors[ver] < 8.0, (ver, errors)

    def test_lut_no_worse_than_taylor(self, errors):
        """Paper §5.1.2: LUT stores exact values, Taylor approximates."""
        assert errors["int32_lut_wram"] <= errors["int32"] + 0.25

    def test_mram_wram_numerically_identical(self, errors):
        """Placement changes cost, not values."""
        assert errors["int32_lut_mram"] == errors["int32_lut_wram"]

    def test_hyb_and_bui_identical(self, errors):
        assert errors["hyb_lut"] == errors["bui_lut"]


class TestLogDecimalsEffect:
    def test_fewer_decimals_helps_hybrid(self, pim):
        """Paper Fig. 7(b): with 2-decimal samples the HYB-LUT error drops
        (8-bit representation is then nearly lossless)."""
        errs = {}
        for dec in (4, 2):
            X, y, _ = make_linear_dataset(4096, 16, decimals=dec, seed=7)
            r = logreg.fit(
                pim.put(X, y),
                logreg.LogRegConfig(version="hyb_lut", n_iters=400))
            errs[dec] = training_error_rate(r.predict(X), y, threshold=0.0)
        assert errs[2] <= errs[4] + 0.3


class TestDtrQuality:
    def test_pim_matches_cpu_accuracy(self, pim, host):
        """Paper §5.1.3: 0.90008 (PIM) vs 0.90175 (CPU) at depth 10."""
        X, y = make_classification(60_000, 16, seed=0, class_sep=1.4)
        accs = []
        for seed in (0, 1):
            t_pim = dtree.fit(pim.put(X, y),
                              dtree.TreeConfig(max_depth=10, seed=seed))
            t_cpu = dtree.fit(host.put(X, y),
                              dtree.TreeConfig(max_depth=10, seed=seed))
            accs.append((accuracy(t_pim.predict(X), y),
                         accuracy(t_cpu.predict(X), y)))
        pim_acc = np.mean([a for a, _ in accs])
        cpu_acc = np.mean([b for _, b in accs])
        assert pim_acc > 0.80
        assert abs(pim_acc - cpu_acc) < 0.04

    def test_depth_limit_respected(self, pim):
        X, y = make_classification(10_000, 16, seed=2)
        t = dtree.fit(pim.put(X, y), dtree.TreeConfig(max_depth=4, seed=0))
        assert int(t.depth[: t.n_nodes].max()) <= 4


class TestKmeQuality:
    def test_pim_cpu_clusterings_nearly_identical(self, pim, host):
        """Paper §5.1.4: ARI ~= 0.999, equal CH scores despite quantization."""
        X, _, _ = make_blobs(20_000, 16, centers=16, seed=0)
        cfg = kmeans.KMeansConfig(k=16, seed=3, n_init=2)
        r_pim = kmeans.fit(pim.put(X), cfg)
        r_cpu = kmeans.fit(host.put(X),
                           kmeans.KMeansConfig(k=16, seed=3, n_init=2,
                                               version="fp32"))
        ari = adjusted_rand_index(r_pim.labels, r_cpu.labels)
        assert ari > 0.95
        ch_pim = calinski_harabasz(X, r_pim.labels)
        ch_cpu = calinski_harabasz(X, r_cpu.labels)
        assert ch_pim == pytest.approx(ch_cpu, rel=0.02)

    def test_converges_under_max_iters(self, pim):
        X, _, _ = make_blobs(8_000, 16, centers=16, seed=1)
        r = kmeans.fit(pim.put(X), kmeans.KMeansConfig(k=16, seed=0))
        assert r.n_iters < 300  # paper: always < 40 in practice
