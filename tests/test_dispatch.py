"""Kernel backend-dispatch layer (repro/kernels/dispatch.py).

Three groups:
  * backend resolution (auto-selection, env override, error paths);
  * backend parity — ``jnp_ref`` vs ``pallas_interpret`` bit-exact for
    the integer kernels, tolerance-bounded for the float kernels,
    including ragged (non-multiple-of-block) shapes;
  * trainer routing — KMeans/DTree/LogReg fits actually go through the
    dispatch layer (asserted via the PimSystem kernel registry names
    AND the dispatch launch counters).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fixed_point import fx_dot, to_fixed
from repro.core.lut import build_sigmoid_lut
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelBackend

BACKENDS = (KernelBackend.JNP_REF, KernelBackend.PALLAS_INTERPRET)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------

def test_resolve_backend_accepts_strings_and_enums():
    assert dispatch.resolve_backend("jnp_ref") is KernelBackend.JNP_REF
    assert dispatch.resolve_backend("PALLAS_INTERPRET".lower()) \
        is KernelBackend.PALLAS_INTERPRET
    for be in KernelBackend:
        assert dispatch.resolve_backend(be) is be or not dispatch.HAS_PALLAS


def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.resolve_backend("cuda")
    with pytest.raises(TypeError):
        dispatch.resolve_backend(7)


def test_default_backend_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.BACKEND_ENV_VAR, "pallas_interpret")
    assert dispatch.default_backend() is KernelBackend.PALLAS_INTERPRET
    monkeypatch.setenv(dispatch.BACKEND_ENV_VAR, "jnp_ref")
    assert dispatch.default_backend() is KernelBackend.JNP_REF


def test_default_backend_off_tpu_is_ref(monkeypatch):
    """Interpret mode must never be the silent default — off-TPU the
    fast path is the fused jnp oracle."""
    monkeypatch.delenv(dispatch.BACKEND_ENV_VAR, raising=False)
    import jax
    if jax.default_backend() != "tpu":
        assert dispatch.default_backend() is KernelBackend.JNP_REF


def test_all_families_registered():
    ops = dispatch.available_ops()
    for op in ("kmeans_assign", "gini_split", "lut_sigmoid",
               "quant_matmul", "int_matmul", "fx_matvec", "mha"):
        assert op in ops
    with pytest.raises(KeyError, match="unknown kernel op"):
        dispatch.get_op("nope")


# ---------------------------------------------------------------------------
# parity: integer kernels are bit-exact across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f,k", [(96, 8, 4), (1000, 16, 16), (33, 4, 2)])
def test_kmeans_assign_backend_parity(n, f, k):
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randint(-2047, 2048, (n, f)), jnp.int16)
    c = jnp.asarray(rng.randint(-2047, 2048, (k, f)), jnp.int16)
    outs = [dispatch.launch("kmeans_assign", x, c, backend=be, block_n=64)
            for be in BACKENDS]
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n,f,L,C", [(100, 3, 4, 2), (257, 8, 8, 3)])
def test_gini_split_backend_parity(n, f, L, C):
    rng = np.random.RandomState(n + L)
    x = jnp.asarray(rng.uniform(0, 1, (n, f)), jnp.float32)
    y = jnp.asarray(rng.randint(0, C, n), jnp.int32)
    leaf = jnp.asarray(rng.randint(0, L, n), jnp.int32)
    th = jnp.asarray(rng.uniform(0, 1, (L, f)), jnp.float32)
    outs = [dispatch.launch("gini_split", x, y, leaf, th, C, backend=be,
                            block_n=64) for be in BACKENDS]
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("shape", [(37,), (13, 5)])
def test_lut_sigmoid_backend_parity(shape):
    lut = build_sigmoid_lut()
    rng = np.random.RandomState(sum(shape))
    xq = to_fixed(jnp.asarray(rng.uniform(-25, 25, shape), jnp.float32), 10)
    a, b = [dispatch.launch("lut_sigmoid", xq, lut, backend=be)
            for be in BACKENDS]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int_matmul_backend_parity():
    rng = np.random.RandomState(3)
    a = jnp.asarray(rng.randint(-128, 128, (32, 64)), jnp.int8)
    b = jnp.asarray(rng.randint(-128, 128, (64, 32)), jnp.int8)
    o1, o2 = [dispatch.launch("int_matmul", a, b, backend=be,
                              bm=32, bn=32, bk=32) for be in BACKENDS]
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("n,f", [(64, 16), (100, 7)])   # incl. ragged tail
def test_fx_matvec_backend_parity_and_oracle(n, f):
    rng = np.random.RandomState(n)
    xq = jnp.asarray(rng.randint(-1024, 1024, (n, f)), jnp.int32)
    wq = jnp.asarray(rng.randint(-1024, 1024, (f,)), jnp.int32)
    outs = [dispatch.launch("fx_matvec", xq, wq, 10, backend=be,
                            block_n=32) for be in BACKENDS]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    # the ref IS fixed_point.fx_dot — the trainers' pre-dispatch hot path
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.asarray(fx_dot(xq, wq, 10)))


def test_mha_backend_parity_tolerance():
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 64, 32)), jnp.float32)
    o1, o2 = [dispatch.launch("mha", q, q, q, backend=be, causal=True,
                              bq=32, bk=32) for be in BACKENDS]
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_fx_matvec_public_wrapper_ragged(use_pallas):
    """The public ops wrapper must pad ragged N like the dispatch path
    (it once called the raw kernel and tripped its block assert)."""
    from repro.kernels.quant_matmul.ops import fx_matvec
    rng = np.random.RandomState(0)
    xq = jnp.asarray(rng.randint(-512, 512, (100, 5)), jnp.int32)
    wq = jnp.asarray(rng.randint(-512, 512, (5,)), jnp.int32)
    out = fx_matvec(xq, wq, 10, use_pallas=use_pallas, block_n=64)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(fx_dot(xq, wq, 10)))


def test_split_eval_kernel_masks_padding_totals():
    """Shard-padding rows must not inflate the spill slot's totals —
    leaf max_nodes-1 is allocatable as a real leaf (parity with the
    pre-dispatch in-line kernel, which masked totals to zero)."""
    from repro.core.dtree import make_split_eval_kernel
    max_nodes, n_classes = 4, 2
    kern = make_split_eval_kernel(max_nodes, n_classes)
    x = jnp.asarray([[0.1], [0.2], [0.3], [0.4], [9.9], [9.9]], jnp.float32)
    y = jnp.asarray([0, 1, 0, 1, 0, 0], jnp.int32)
    # two real points live in the spill leaf max_nodes-1
    leaf = jnp.asarray([0, 0, 3, 3, 0, 0], jnp.int32)
    valid = jnp.asarray([1, 1, 1, 1, 0, 0], bool)
    th = jnp.full((max_nodes, 1), 0.5, jnp.float32)
    out = kern(x, y, leaf, valid, th)
    np.testing.assert_array_equal(np.asarray(out["total"]),
                                  [[1, 1], [0, 0], [0, 0], [1, 1]])
    assert int(out["total"].sum()) == 4  # only the valid rows


def test_pallas_backend_degrades_to_ref_when_unavailable(monkeypatch):
    monkeypatch.setattr(dispatch, "HAS_PALLAS", False)
    assert dispatch.resolve_backend("pallas_tpu") is KernelBackend.JNP_REF
    assert dispatch.resolve_backend("pallas_interpret") \
        is KernelBackend.JNP_REF


# ---------------------------------------------------------------------------
# trainer routing: fits go through the dispatch layer
# ---------------------------------------------------------------------------

def _count(op):
    return dispatch.launch_counts.get(op, 0)


def _toy(n=60, f=6, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.normal(0, 1, (n, f)).astype(np.float32)
    y = (X[:, 0] + 0.3 * X[:, 1] > 0).astype(np.int32)
    return X, y


def test_kmeans_fit_routes_through_dispatch():
    from repro.core import kmeans
    from repro.core.pim import PimConfig, PimSystem
    X, _ = _toy()
    pim = PimSystem(PimConfig(n_cores=2))
    before = _count("kmeans_assign")
    r = kmeans.fit(pim.put(X), kmeans.KMeansConfig(k=3, max_iters=4))
    assert _count("kmeans_assign") > before
    tag = dispatch.backend_tag(None)
    assert f"kme.assign/k3/{tag}" in pim.registered_kernels()
    assert r.labels is not None and r.labels.shape == (X.shape[0],)


def test_dtree_fit_routes_through_dispatch():
    from repro.core import dtree
    from repro.core.pim import PimConfig, PimSystem
    X, y = _toy()
    pim = PimSystem(PimConfig(n_cores=2))
    before = _count("gini_split")
    tree = dtree.fit(pim.put(X, y), dtree.TreeConfig(max_depth=3))
    assert _count("gini_split") > before
    tag = dispatch.backend_tag(None)
    assert any(k.startswith("dtr.eval/") and k.endswith(tag)
               for k in pim.registered_kernels())
    assert tree.n_nodes >= 1


def test_logreg_fit_routes_through_dispatch():
    from repro.core import logreg
    from repro.core.pim import PimConfig, PimSystem
    X, y = _toy()
    pim = PimSystem(PimConfig(n_cores=2))
    before_mv, before_lut = _count("fx_matvec"), _count("lut_sigmoid")
    logreg.fit(pim.put(X, y),
               logreg.LogRegConfig(version="int32_lut_wram", n_iters=3))
    assert _count("fx_matvec") > before_mv
    assert _count("lut_sigmoid") > before_lut


def test_trainer_results_backend_invariant():
    """jnp_ref and pallas_interpret produce identical fits (integer
    kernels are deterministic; the backend is a pure performance knob)."""
    from repro.core import dtree, kmeans
    from repro.core.pim import PimConfig, PimSystem
    X, y = _toy(n=48, f=5)
    results = {}
    for be in ("jnp_ref", "pallas_interpret"):
        pim = PimSystem(PimConfig(n_cores=2))
        km = kmeans.fit(pim.put(X), kmeans.KMeansConfig(
            k=3, max_iters=4, kernel_backend=be))
        tr = dtree.fit(pim.put(X, y), dtree.TreeConfig(
            max_depth=3, kernel_backend=be))
        results[be] = (km.inertia, km.labels, tr.feature.copy(),
                       tr.threshold.copy(), tr.n_nodes)
    a, b = results["jnp_ref"], results["pallas_interpret"]
    assert a[0] == b[0]
    np.testing.assert_array_equal(a[1], b[1])
    np.testing.assert_array_equal(a[2], b[2])
    np.testing.assert_array_equal(a[3], b[3])
    assert a[4] == b[4]


def test_estimator_exposes_kernel_backend():
    from repro.api import make_estimator
    from repro.core.pim import PimConfig, PimSystem
    X, _ = _toy()
    est = make_estimator("kmeans", n_clusters=3, max_iter=4,
                         kernel_backend="jnp_ref",
                         system=PimSystem(PimConfig(n_cores=2)))
    est.fit(X)
    assert est.get_params()["kernel_backend"] == "jnp_ref"
