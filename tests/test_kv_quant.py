"""int8 KV-cache quantization (§Perf bonus iteration)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.api import Model
from repro.models.attention import dequantize_kv, quantize_kv


def test_quantize_kv_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.normal(0, 3, (2, 4, 16, 64)), jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 4, 16)
    back = dequantize_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(back - x))
    # per-vector scale -> error <= scale/2 per element
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert (err <= bound).all()


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-8b"])
def test_int8_kv_decode_close_and_greedy_agrees(arch):
    cfg16 = get_config(arch).reduced()
    cfg8 = dataclasses.replace(cfg16, kv_cache_bits=8)
    S = 24
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg16.vocab_size, (2, S)))
    m16, m8 = Model(cfg16), Model(cfg8)
    params = m16.init(jax.random.PRNGKey(0))
    outs = {}
    for name, m in (("bf16", m16), ("int8", m8)):
        _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, max_seq=S)
        dec, _ = m.decode_step(params, toks[:, -1:], cache)
        outs[name] = np.asarray(dec[:, 0], np.float32)
    rel = np.abs(outs["int8"] - outs["bf16"]).max() / \
        np.abs(outs["bf16"]).max()
    assert rel < 0.05, rel
    # greedy decisions agree
    assert (outs["int8"].argmax(-1) == outs["bf16"].argmax(-1)).all()


def test_int8_cache_multi_step_decode_stable():
    """Quantization error must not compound over decode steps."""
    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              kv_cache_bits=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 8)))
    _, cache = model.prefill(params, {"tokens": toks}, max_seq=32)
    t = toks[:, -1:]
    for _ in range(16):
        logits, cache = model.decode_step(params, t, cache)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        t = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1).astype(
            jnp.int32)
    # cache advanced without error through all 16 quantized writes
    length = int(np.asarray(cache[0]["kv"].length).max())
    assert length == 8 + 16
