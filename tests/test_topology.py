"""Hierarchical cost model + topology-aware placement (DESIGN.md §12).

Four test families:
  * topology geometry — rank/channel trees, footprints, segmented
    MRAM<->WRAM DMA cost;
  * calibration — modeled Fig. 8-10 version-ratio and Fig. 11-12
    strong-scaling numbers against the paper's measured values, each
    with a stated error bound;
  * allocator invariants — lease footprints always match the topology,
    coalescing restores per-channel occupancy to zero, contention
    placement is deterministic and spreads across channels;
  * consumers — scheduler stats/capacity_estimate, the placement
    benchmark's contention-beats-first-fit claim, the A100 roofline's
    calibrated GPU column, and the DpuCostModel deprecation shim.
"""
import os
import sys

import pytest

import repro.systems.pim as pim_mod
from repro.launch.roofline import a100
from repro.sched import BankAllocator, PLACEMENT_POLICIES, PimScheduler
from repro.systems import make_system
from repro.systems.topology import (DPU_DMA_SEGMENT_BYTES,
                                    DPU_DMA_SETUP_CYCLES,
                                    DPU_MRAM_BYTES_PER_CYCLE,
                                    ExtentFootprint, HierarchicalCostModel,
                                    PimTopology, default_rank_size)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)       # benchmarks/ is a repo-root package


# ---------------------------------------------------------------------------
# Topology geometry.
# ---------------------------------------------------------------------------

def test_tree_geometry():
    topo = PimTopology(n_cores=512, dpus_per_rank=64, ranks_per_channel=2)
    assert topo.n_ranks == 8
    assert topo.n_channels == 4
    assert topo.cores_per_channel == 128
    assert topo.rank_of(0) == 0 and topo.rank_of(63) == 0
    assert topo.rank_of(64) == 1
    assert topo.channel_of(127) == 0 and topo.channel_of(128) == 1


def test_footprint_spans_partial_ranks():
    topo = PimTopology(n_cores=512, dpus_per_rank=64, ranks_per_channel=2)
    fp = topo.footprint(32, 64)            # straddles ranks 0 and 1
    assert fp.ranks == (0, 1)
    assert fp.channels == (0,)
    assert fp.rank_straddling and not fp.channel_straddling
    fp2 = topo.footprint(96, 64)           # ranks 1-2 -> channels 0-1
    assert fp2.channels == (0, 1) and fp2.channel_straddling


def test_footprint_rejects_out_of_machine_extents():
    topo = PimTopology(n_cores=128)
    with pytest.raises(ValueError):
        topo.footprint(100, 64)
    with pytest.raises(ValueError):
        topo.footprint(0, 0)


def test_for_cores_matches_allocator_rank_heuristic():
    for n in (16, 64, 96, 100, 2048):
        topo = PimTopology.for_cores(n)
        assert topo.dpus_per_rank == default_rank_size(n)
        assert n % topo.dpus_per_rank == 0


def test_wram_mram_fit_checks():
    topo = PimTopology(n_cores=1)
    assert topo.wram_fits(64 * 1024) and not topo.wram_fits(64 * 1024 + 1)
    assert topo.mram_fits(64 << 20) and not topo.mram_fits((64 << 20) + 1)


def test_segmented_dma_has_small_transfer_cliff():
    """Per-byte cost at 8 B is far above the streaming rate (the
    measured UPMEM small-DMA latency cliff); large transfers converge
    to the flat bytes/1.6 model within the per-segment setup."""
    topo = PimTopology(n_cores=1)
    assert topo.mram_wram_cycles(0) == 0.0
    small = topo.mram_wram_cycles(8) / 8
    big_bytes = 64 * DPU_DMA_SEGMENT_BYTES
    big = topo.mram_wram_cycles(big_bytes) / big_bytes
    assert small / big > 10.0
    flat = big_bytes / DPU_MRAM_BYTES_PER_CYCLE
    assert topo.mram_wram_cycles(big_bytes) == pytest.approx(
        flat + 64 * DPU_DMA_SETUP_CYCLES)


# ---------------------------------------------------------------------------
# Cost-model guards + calibration against the paper.
# ---------------------------------------------------------------------------

def test_kernel_seconds_rejects_non_positive_threads():
    """Regression: n_threads=0 used to price as near-infinite compute
    instead of failing loudly (degenerate lease)."""
    m = HierarchicalCostModel.for_cores(1)
    for bad in (0, -1):
        with pytest.raises(ValueError, match="n_threads"):
            m.kernel_seconds(1e6, 0, bad)
    # boundary stays priced
    assert m.kernel_seconds(1e6, 0, 1) > 0


#: paper-measured version-ratio ladder (Figs. 8-9, §5.2.1-§5.2.2) and
#: the bound the calibrated tables must hold it to.
PAPER_RATIOS = {
    "lin_fp32_over_int32": 8.5,
    "lin_int32_over_hyb": 1.41,
    "lin_hyb_over_bui": 1.25,
    "log_int32_over_lut_wram": 53.0,
    "log_lut_mram_over_wram": 1.03,
    "log_lut_wram_over_hyb": 1.28,
    "log_hyb_over_bui": 1.43,
}
RATIO_REL_TOL = 0.15


def _modeled_ratios():
    m = HierarchicalCostModel.for_cores(1)

    def sec(w, v):
        return m.workload_seconds(w, v, 2048, 16, 1, 16)

    return {
        "lin_fp32_over_int32": sec("lin", "fp32") / sec("lin", "int32"),
        "lin_int32_over_hyb": sec("lin", "int32") / sec("lin", "hyb"),
        "lin_hyb_over_bui": sec("lin", "hyb") / sec("lin", "bui"),
        "log_int32_over_lut_wram": sec("log", "int32")
        / sec("log", "int32_lut_wram"),
        "log_lut_mram_over_wram": sec("log", "int32_lut_mram")
        / sec("log", "int32_lut_wram"),
        "log_lut_wram_over_hyb": sec("log", "int32_lut_wram")
        / sec("log", "hyb_lut"),
        "log_hyb_over_bui": sec("log", "hyb_lut") / sec("log", "bui_lut"),
    }


@pytest.mark.parametrize("key", sorted(PAPER_RATIOS))
def test_fig08_10_version_ratios_within_bound(key):
    modeled = _modeled_ratios()[key]
    paper = PAPER_RATIOS[key]
    rel_err = abs(modeled - paper) / paper
    assert rel_err <= RATIO_REL_TOL, (
        f"{key}: modeled {modeled:.3f} vs paper {paper} "
        f"(rel err {rel_err:.3f} > {RATIO_REL_TOL})")


#: Fig. 12: the measured 2048-vs-256-core speedup band.  The flat model
#: predicted exactly 8.0x; the hierarchical model's rank-serialized
#: legs pull every workload into the measured band.
STRONG_SCALING_BAND = (6.37, 7.98)


@pytest.mark.parametrize("w,v,n", [
    ("lin", "int32", 6_291_456),
    ("log", "int32_lut_wram", 6_291_456),
])
def test_fig11_12_strong_scaling_in_paper_band(w, v, n):
    def step(cores):
        m = HierarchicalCostModel.for_cores(cores)
        return m.step_seconds(w, v, n, 16, n_cores=cores, n_threads=16)

    speedup = step(256) / step(2048)
    lo, hi = STRONG_SCALING_BAND
    assert lo < speedup < hi, f"{w}/{v}: {speedup:.2f} outside paper band"


@pytest.mark.slow
@pytest.mark.parametrize("w,v,n", [
    ("dtr", "fp32", 153_600_000),
    ("kme", "int16", 25_600_000),
])
def test_fig12_strong_scaling_sweep_remaining_workloads(w, v, n):
    """Calibration sweep over the remaining (much larger) Fig. 12
    datasets — same band, kept out of the fast tier."""
    def step(cores):
        m = HierarchicalCostModel.for_cores(cores)
        return m.step_seconds(w, v, n, 16, n_cores=cores, n_threads=16)

    speedup = step(256) / step(2048)
    lo, hi = STRONG_SCALING_BAND
    assert lo < speedup < hi


def test_transfer_legs_serialize_ranks_and_split_bandwidth():
    m = HierarchicalCostModel.for_cores(128, dpus_per_rank=64,
                                        ranks_per_channel=2)
    one_rank = m.broadcast_seconds(1024, 64, start=0)
    two_ranks = m.broadcast_seconds(1024, 128, start=0)
    # both ranks share one channel: the legs serialize (two setups, one
    # bandwidth), so 128 cores cost strictly more than 2x is not needed
    # but strictly more than one rank is
    assert two_ranks > one_rank * 1.9
    # a co-tenant on the channel halves the share -> byte term doubles
    contended = m.broadcast_seconds(1024, 64, start=0, sharers=2)
    assert contended > one_rank
    assert m.broadcast_seconds(0, 64) == 0.0


def test_contention_sharers_counts_busiest_channel():
    m = HierarchicalCostModel.for_cores(256, dpus_per_rank=64,
                                        ranks_per_channel=2)
    # extent on channel 0; one tenant on the same channel, one elsewhere
    assert m.contention_sharers(0, 64, [(64, 64), (128, 64)]) == 2
    assert m.contention_sharers(0, 64, [(128, 64), (192, 64)]) == 1
    assert m.contention_sharers(0, 64, []) == 1


# ---------------------------------------------------------------------------
# Allocator topology invariants (property-style over a fixed sequence).
# ---------------------------------------------------------------------------

def _churn(alloc):
    """Deterministic allocate/release churn; returns live leases."""
    live = {}
    seq = [("a", "j1", 64), ("a", "j2", 128), ("a", "j3", 64),
           ("r", "j2", 0), ("a", "j4", 64), ("a", "j5", 192),
           ("r", "j1", 0), ("a", "j6", 128), ("r", "j4", 0),
           ("a", "j7", 64)]
    for op, name, size in seq:
        if op == "a":
            lease = alloc.allocate(size)
            assert lease is not None, f"{name} did not fit"
            live[name] = lease
        else:
            alloc.release(live.pop(name))
    return live


@pytest.mark.parametrize("placement", PLACEMENT_POLICIES)
def test_lease_footprints_always_match_topology(placement):
    """Invariant: every live lease's ranks/channels are exactly what
    the topology derives from its extent — across churn, under both
    placement policies."""
    topo = PimTopology(n_cores=1024, dpus_per_rank=64, ranks_per_channel=2)
    alloc = BankAllocator(1024, rank_size=64, topology=topo,
                          placement=placement)
    live = _churn(alloc)
    assert live
    for lease in alloc.leases:
        fp = topo.footprint(lease.start, lease.n_cores)
        assert lease.ranks == fp.ranks
        assert lease.channels == fp.channels
        assert lease.rank_straddling == fp.rank_straddling


@pytest.mark.parametrize("placement", PLACEMENT_POLICIES)
def test_release_all_restores_zero_channel_occupancy(placement):
    """Invariant: coalescing reclaim returns every channel to zero
    occupancy and one maximal free extent."""
    alloc = BankAllocator(1024, rank_size=64, placement=placement)
    live = _churn(alloc)
    assert any(v > 0 for v in alloc.channel_occupancy().values())
    for lease in list(live.values()):
        alloc.release(lease)
    occ = alloc.channel_occupancy()
    assert all(v == 0.0 for v in occ.values())
    frag = alloc.fragmentation()
    assert frag.per_channel_occupancy == tuple([0.0] * len(occ))
    assert frag.n_free_extents == 1
    assert frag.largest_free_extent == 1024
    assert frag.rank_straddling_leases == 0


def test_contention_placement_is_deterministic():
    """Two identically-configured allocators given the same request
    sequence grant identical extents (the score tuple ends in `start`,
    so ties cannot wander)."""
    def run():
        alloc = BankAllocator(1024, rank_size=64, placement="contention")
        leases = _churn(alloc)
        return sorted((ls.start, ls.n_cores) for ls in alloc.leases), leases
    a, _ = run()
    b, _ = run()
    assert a == b


def test_contention_placement_spreads_across_channels():
    """Fresh machine, four 1-rank tenants: contention placement puts
    each on its own memory channel; first-fit stacks two per channel."""
    def channels(placement):
        topo = PimTopology(n_cores=512, dpus_per_rank=64,
                           ranks_per_channel=2)     # 4 channels
        alloc = BankAllocator(512, rank_size=64, topology=topo,
                              placement=placement)
        out = []
        for _ in range(4):
            out.append(alloc.allocate(64).channels)
        return [ch for cs in out for ch in cs]

    spread = channels("contention")
    assert sorted(spread) == [0, 1, 2, 3]
    packed = channels("first_fit")
    assert sorted(packed) == [0, 0, 1, 1]


def test_unknown_placement_rejected():
    with pytest.raises(ValueError, match="placement"):
        BankAllocator(128, placement="psychic")


# ---------------------------------------------------------------------------
# Scheduler consumers: stats surface + capacity_estimate.
# ---------------------------------------------------------------------------

def _tiny_manifest():
    return {
        "system": {"kind": "pim", "cores": 128, "rank_size": 64},
        "datasets": {"d": {"kind": "linear", "samples": 2048,
                           "features": 16}},
        "jobs": [{"workload": "linreg", "version": "int32", "dataset": "d",
                  "cores": 64, "params": {"n_iters": 40}}],
        "sweeps": [{"workload": "linreg", "dataset": "d",
                    "grid": {"lr": [0.05, 0.1]}, "cores": 64,
                    "params": {"n_iters": 40}}],
    }


def test_scheduler_stats_report_channel_occupancy():
    sched = PimScheduler(make_system("pim", n_cores=128), rank_size=64,
                         placement="contention")
    st = sched.stats()
    assert "per_channel_occupancy" in st
    assert "rank_straddling_leases" in st
    for per_target in st["targets"].values():
        assert "per_channel_occupancy" in per_target
        assert "rank_straddling_leases" in per_target


def test_capacity_estimate_prices_manifest_without_running_it():
    sched = PimScheduler(make_system("pim", n_cores=128), rank_size=64)
    est = sched.capacity_estimate(_tiny_manifest())
    assert est["machine_cores"] == 128
    assert len(est["jobs"]) == 3            # 1 job + 2 sweep points
    assert all(r["modeled_seconds"] > 0 for r in est["jobs"])
    assert est["serial_seconds"] == pytest.approx(
        sum(r["modeled_seconds"] for r in est["jobs"]))
    # the bound is sandwiched between longest-job and serial time
    longest = max(r["modeled_seconds"] for r in est["jobs"])
    assert longest <= est["makespan_lower_bound"] <= est["serial_seconds"]
    with pytest.raises(ValueError):
        sched.capacity_estimate({"jobs": []})


def test_placement_bench_contention_beats_first_fit():
    """The acceptance claim of benchmarks/placement_bench.py, asserted
    directly (pure cost-model arithmetic, milliseconds)."""
    from benchmarks.placement_bench import simulate
    ff = simulate("first_fit")
    ca = simulate("contention")
    assert ca["makespan_s"] <= ff["makespan_s"]
    assert ca["mean_sharers"] <= ff["mean_sharers"]


# ---------------------------------------------------------------------------
# GPU roofline calibration (Fig. 13 GPU column).
# ---------------------------------------------------------------------------

def test_gpu_roofline_calibration_constants():
    rl = a100()
    assert rl.achievable_bw == pytest.approx(0.85 * 1.555e12)
    # memory-bound kernel is priced at the sustained rate, not datasheet
    nbytes = 1e9
    t = rl.kernel_seconds(0.0, nbytes)
    assert t == pytest.approx(rl.launch_overhead_s
                              + nbytes / rl.achievable_bw)
    # tiny kernels pay the launch floor
    assert rl.kernel_seconds(0.0, 0.0) == rl.launch_overhead_s


def test_fig13_gpu_column_ratio_within_paper_band():
    """LIN at paper scale (SUSY 5M x 18): the modeled PIM-over-GPU
    ratio must land in a coarse band around the paper's measured 4.1x
    (GPU faster).  Analytic GD per-iteration terms: ~4nF FLOPs, ~2nF
    f32 reads per step."""
    n, f = 5_000_000, 18
    pim = HierarchicalCostModel.for_cores(2524, dpus_per_rank=64) \
        .step_seconds("lin", "bui", n, f, n_cores=2524, n_threads=16)
    gpu = a100().kernel_seconds(4.0 * n * f, 2.0 * n * f * 4)
    ratio = pim / gpu                       # paper: 4.1 (GPU wins)
    assert 1.5 < ratio < 8.0, f"pim/gpu {ratio:.2f} vs paper 4.1"


# ---------------------------------------------------------------------------
# Deprecation shim.
# ---------------------------------------------------------------------------

def test_dpu_cost_model_shim_warns_once(monkeypatch):
    monkeypatch.setattr(pim_mod, "_DPU_COST_MODEL_WARNED", False)
    import warnings as w
    with w.catch_warnings(record=True) as rec:
        w.simplefilter("always")
        first = pim_mod.DpuCostModel()
        pim_mod.DpuCostModel()
    deps = [r for r in rec if issubclass(r.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "HierarchicalCostModel" in str(deps[0].message)
    # the shim IS the hierarchical model's single-core leaf
    assert isinstance(first, HierarchicalCostModel)
    assert first.topology.n_cores == 1
    ref = HierarchicalCostModel.for_cores(1)
    assert first.workload_seconds("lin", "int32", 2048, 16, 1, 16) == \
        ref.workload_seconds("lin", "int32", 2048, 16, 1, 16)


def test_footprint_dataclass_props():
    fp = ExtentFootprint(ranks=(3,), channels=(1,))
    assert not fp.rank_straddling and not fp.channel_straddling
