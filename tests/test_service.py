"""Async training service (DESIGN.md §14): background serve loop,
SLO-aware admission, deadline scheduling, and the manifest spool.

Covers the serve/shutdown lifecycle (no lost jobs), queue/completion
latency accounting, submit- and manifest-level ``max_modeled_seconds``
admission (FAILED handle / SloViolation — never a crash), deadline
(EDF) queue ordering and eviction, and mid-flight manifest admission
through ``serve_manifests``; the Poisson soak and the end-to-end
``pim_jobs --serve`` CLI run are marked ``slow``.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.api import PimConfig, PimSystem
from repro.data.synthetic import make_linear_dataset
from repro.sched import (JobState, PimScheduler, SloViolation,
                         serve_manifests, submit_manifest)

N, F = 192, 6


@pytest.fixture(scope="module")
def lin_data():
    X, y, _ = make_linear_dataset(N, F, seed=0)
    return X, y


def _sched(cores=8, rank=4, **kw):
    return PimScheduler(PimSystem(PimConfig(n_cores=cores)),
                        rank_size=rank, **kw)


def _manifest_doc(n_iters=20, name="job", cores=4):
    return {
        "system": {"cores": 8, "rank_size": 4},
        "datasets": {"lin": {"kind": "linear", "samples": N,
                             "features": F, "seed": 0}},
        "jobs": [
            {"workload": "linreg", "dataset": "lin", "cores": cores,
             "version": "int32", "name": name,
             "params": {"n_iters": n_iters, "fuse_steps": 5}},
        ],
    }


# ---------------------------------------------------------------------------
# Serve lifecycle: background drain, wait, shutdown without job loss.
# ---------------------------------------------------------------------------

def test_serve_lifecycle_and_latency(lin_data):
    X, y = lin_data
    s = _sched()
    assert not s.serving and s.idle
    s.serve(poll_interval=0.005)
    assert s.serving
    with pytest.raises(RuntimeError):
        s.serve()                      # one drain loop per scheduler
    handles = [s.submit("linreg", (X, y), version="int32", n_cores=4,
                        n_iters=20, fuse_steps=5, name=f"j{i}")
               for i in range(3)]
    assert s.wait(handles, timeout=60.0)
    assert all(h.state is JobState.DONE for h in handles)
    for h in handles:
        assert h.queue_latency is not None and h.queue_latency >= 0.0
        assert h.completion_latency >= h.queue_latency
        m = h.metrics()
        assert m["queue_latency"] == h.queue_latency
        assert m["deadline_missed"] is False
    lat = s.latency_summary()
    assert lat["completion"]["count"] == 3
    assert lat["queue"]["p50"] <= lat["queue"]["p99"]
    stats = s.stats()
    assert stats["serving"] and stats["latency"]["completion"]["count"] == 3
    s.shutdown(wait=True)
    assert not s.serving and s.idle


def test_shutdown_drains_submitted_jobs(lin_data):
    """shutdown(wait=True) is a drain barrier: every job submitted
    before the call reaches a terminal state — none lost."""
    X, y = lin_data
    s = _sched()
    s.serve(poll_interval=0.005)
    handles = [s.submit("linreg", (X, y), version="int32", n_cores=4,
                        n_iters=15, fuse_steps=5) for _ in range(4)]
    s.shutdown(wait=True)
    assert all(h.state is JobState.DONE for h in handles)
    assert s.idle and not s.serving
    # shutdown is idempotent; serve can restart after a clean stop
    s.shutdown(wait=True)
    s.serve(poll_interval=0.005)
    h = s.submit("linreg", (X, y), version="int32", n_cores=4,
                 n_iters=10, fuse_steps=5)
    assert s.wait([h], timeout=60.0) and h.state is JobState.DONE
    s.shutdown(wait=True)


# ---------------------------------------------------------------------------
# SLO admission: the cost model answers before anything runs.
# ---------------------------------------------------------------------------

def test_submit_slo_rejection_is_failed_not_crash(lin_data):
    X, y = lin_data
    s = _sched()
    h = s.submit("linreg", (X, y), version="int32", n_cores=4,
                 n_iters=400, max_modeled_seconds=1e-12)
    assert h.state is JobState.FAILED
    assert isinstance(h.error, SloViolation)
    assert "max_modeled_seconds" in str(h.error)
    assert s.idle                       # never queued
    assert s.metrics.counter("sched.slo_rejections").value == 1
    # a permissive bound on the same scheduler still admits
    ok = s.submit("linreg", (X, y), version="int32", n_cores=4,
                  n_iters=10, fuse_steps=5, max_modeled_seconds=1e9)
    s.drain()
    assert ok.state is JobState.DONE


def test_scheduler_default_slo_bound(lin_data):
    X, y = lin_data
    s = _sched(max_modeled_seconds=1e-12)
    h = s.submit("linreg", (X, y), version="int32", n_cores=4, n_iters=50)
    assert h.state is JobState.FAILED and isinstance(h.error, SloViolation)
    # per-submit bound overrides the scheduler default
    ok = s.submit("linreg", (X, y), version="int32", n_cores=4,
                  n_iters=10, fuse_steps=5, max_modeled_seconds=1e9)
    s.drain()
    assert ok.state is JobState.DONE


def test_manifest_slo_rejected_whole(lin_data):
    s = _sched()
    doc = _manifest_doc(n_iters=200)
    doc["slo"] = {"max_modeled_seconds": 1e-12}
    with pytest.raises(SloViolation, match="makespan lower bound"):
        submit_manifest(s, doc)
    assert s.idle                       # nothing queued
    assert s.metrics.counter("sched.manifest_slo_rejections").value == 1
    # without the slo section the same manifest is admitted
    del doc["slo"]
    handles = submit_manifest(s, doc)
    s.drain()
    assert all(h.state is JobState.DONE for h in handles)


# ---------------------------------------------------------------------------
# Deadline (EDF) policy: ordering and eviction.
# ---------------------------------------------------------------------------

def test_deadline_policy_orders_queue(lin_data):
    X, y = lin_data
    s = _sched(cores=4, rank=4, policy="deadline")   # one job at a time
    kw = dict(version="int32", n_cores=4, n_iters=10, fuse_steps=5)
    a = s.submit("linreg", (X, y), name="no-deadline", **kw)
    b = s.submit("linreg", (X, y), name="late", deadline_seconds=100.0,
                 **kw)
    c = s.submit("linreg", (X, y), name="soon", deadline_seconds=10.0,
                 **kw)
    s.drain()
    assert all(h.state is JobState.DONE for h in (a, b, c))
    # earliest deadline first; deadline-less jobs run last
    assert c.started_at < b.started_at < a.started_at


def test_deadline_outranks_evicts_at_chunk_boundary(lin_data):
    X, y = lin_data
    s = _sched(cores=4, rank=4, policy="deadline", preemptive=True)
    kw = dict(version="int32", n_cores=4, n_iters=40, fuse_steps=4)
    victim = s.submit("linreg", (X, y), name="no-deadline", **kw)
    s.step()
    assert victim.state is JobState.RUNNING
    urgent = s.submit("linreg", (X, y), name="urgent",
                      deadline_seconds=5.0, **kw)
    s.step()
    # evicted at the chunk boundary, back in the queue behind the
    # deadline job, holding its boundary snapshot
    assert victim.preemptions == 1
    assert victim.state is JobState.QUEUED
    assert urgent.state is JobState.RUNNING
    s.drain()
    assert urgent.state is JobState.DONE
    assert urgent.deadline_missed is False
    assert victim.state is JobState.DONE and victim.iters == 40
    assert urgent.finished_at < victim.finished_at


def test_fifo_policy_ignores_deadline_ordering(lin_data):
    X, y = lin_data
    s = _sched(cores=4, rank=4)        # fifo default
    kw = dict(version="int32", n_cores=4, n_iters=10, fuse_steps=5)
    a = s.submit("linreg", (X, y), **kw)
    b = s.submit("linreg", (X, y), deadline_seconds=1e-3, **kw)
    s.drain()
    assert a.started_at < b.started_at
    assert b.deadline_missed or b.completion_latency >= 0.0


def test_bad_policy_rejected():
    with pytest.raises(ValueError, match="policy"):
        _sched(policy="lifo")


# ---------------------------------------------------------------------------
# Manifest spool: mid-flight admission with sidecar verdicts.
# ---------------------------------------------------------------------------

def test_serve_manifests_mid_flight(tmp_path, lin_data):
    s = _sched()
    handles = submit_manifest(s, _manifest_doc(n_iters=30, name="first"))
    spool = tmp_path / "spool"
    spool.mkdir()
    (spool / "m1.json").write_text(
        json.dumps(_manifest_doc(n_iters=20, name="second")))

    def drop_late():
        time.sleep(0.3)
        (spool / "m2.json").write_text(
            json.dumps(_manifest_doc(n_iters=10, name="third")))

    t = threading.Thread(target=drop_late)
    t.start()
    records = serve_manifests(s, str(spool), poll_interval=0.02,
                              idle_timeout=1.0, handles=handles)
    t.join()
    s.shutdown(wait=True)
    assert [r["state"] for r in records] == ["accepted", "accepted"]
    assert len(handles) == 3
    assert all(h.state is JobState.DONE for h in handles)
    # sidecar verdicts: durable, and not re-scanned as manifests
    for name in ("m1.json", "m2.json"):
        sidecar = json.loads((spool / (name + ".status.json")).read_text())
        assert sidecar["state"] == "accepted" and sidecar["jobs"] == 1


def test_serve_manifests_rejects_bad_and_slo_manifests(tmp_path, lin_data):
    s = _sched()
    spool = tmp_path / "spool"
    spool.mkdir()
    ok = _manifest_doc(n_iters=10, name="ok")
    (spool / "a_ok.json").write_text(json.dumps(ok))
    bad = _manifest_doc(name="bad")
    bad["jobs"][0]["dataset"] = "nope"
    (spool / "b_bad.json").write_text(json.dumps(bad))
    slo = _manifest_doc(n_iters=300, name="slo")
    slo["slo"] = {"max_modeled_seconds": 1e-12}
    (spool / "c_slo.json").write_text(json.dumps(slo))
    (spool / "notes.txt").write_text("not a manifest")

    handles = []
    records = serve_manifests(s, str(spool), poll_interval=0.02,
                              idle_timeout=0.8, handles=handles)
    s.shutdown(wait=True)
    by_name = {os.path.basename(r["path"]): r for r in records}
    assert by_name["a_ok.json"]["state"] == "accepted"
    assert by_name["b_bad.json"]["state"] == "rejected"
    assert "unknown dataset" in by_name["b_bad.json"]["reason"]
    assert by_name["c_slo.json"]["state"] == "rejected"
    assert "SloViolation" in by_name["c_slo.json"]["reason"]
    assert "notes.txt" not in by_name
    assert len(handles) == 1 and handles[0].state is JobState.DONE
    # a rejected manifest's sidecar stops it being re-tried next scan
    sidecar = json.loads(
        (spool / "b_bad.json.status.json").read_text())
    assert sidecar["state"] == "rejected"


# ---------------------------------------------------------------------------
# Sustained load + the CLI face (slow tier).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_poisson_soak_no_lost_jobs(lin_data):
    """Poisson arrivals onto a serving scheduler: every job terminal,
    latency accounting complete, no serve-loop errors."""
    X, y = lin_data
    rng = np.random.RandomState(7)
    s = _sched(cores=16, rank=4, policy="deadline")
    s.serve(poll_interval=0.005)
    handles = []
    for i in range(12):
        time.sleep(float(rng.exponential(0.02)))
        handles.append(s.submit(
            "linreg", (X, y), version="int32", n_cores=4,
            n_iters=15, fuse_steps=5, deadline_seconds=30.0,
            name=f"soak{i}"))
    assert s.wait(handles, timeout=120.0)
    s.shutdown(wait=True)
    assert all(h.state is JobState.DONE for h in handles)
    lat = s.latency_summary()
    assert lat["completion"]["count"] == 12
    assert s.metrics.counter("sched.serve_errors").value == 0


@pytest.mark.slow
def test_cli_serve_accepts_manifest_mid_flight(tmp_path, lin_data):
    """pim_jobs --serve end to end: initial manifest drains on the
    background thread, a spooled manifest lands mid-flight, both reach
    terminal states, and the JSON report records the spool verdicts."""
    from repro.launch import pim_jobs
    manifest = tmp_path / "initial.json"
    manifest.write_text(json.dumps(_manifest_doc(n_iters=40,
                                                 name="initial")))
    spool = tmp_path / "spool"
    spool.mkdir()
    out = tmp_path / "report.json"

    def drop_late():
        time.sleep(0.3)
        (spool / "late.json").write_text(
            json.dumps(_manifest_doc(n_iters=10, name="late")))

    t = threading.Thread(target=drop_late)
    t.start()
    rc = pim_jobs.main([str(manifest), "--serve", "--spool", str(spool),
                        "--poll-interval", "0.02",
                        "--idle-timeout", "1.0",
                        "--json", str(out)])
    t.join()
    assert rc == 0
    report = json.loads(out.read_text())
    assert {j["state"] for j in report["jobs"]} == {"done"}
    assert len(report["jobs"]) == 2
    assert [m["state"] for m in report["manifests"]] == ["accepted"]
    assert report["scheduler"]["latency"]["completion"]["count"] == 2
