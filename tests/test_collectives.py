"""Hierarchical two-level all-reduce == flat psum (multi-pod schedule)."""
import subprocess

import pytest
import sys

from repro.distributed.collectives import cross_pod_bytes


def test_cross_pod_bytes_napkin():
    flat, hier = cross_pod_bytes(1 << 30, 16)
    assert hier * 16 == flat


@pytest.mark.slow
def test_hierarchical_psum_matches_flat_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.collectives import hierarchical_psum

mesh = jax.make_mesh((2, 4), ("pod", "data"))

@functools.partial(shard_map, mesh=mesh,
                   in_specs=P(("pod", "data")), out_specs=P())
def flat(x):
    return jax.lax.psum(x, ("pod", "data"))

# check_vma=False: the RS -> inter-AR -> AG composition is replicated in
# value, but shard_map's varying-axes type system cannot infer that
# (repro.compat translates the kwarg for older jax).
@functools.partial(shard_map, mesh=mesh,
                   in_specs=P(("pod", "data")), out_specs=P(),
                   check_vma=False)
def hier(x):
    return hierarchical_psum(x, intra_axis="data", inter_axis="pod")

x = jnp.arange(8 * 12, dtype=jnp.float32).reshape(8 * 4, 3) / 7.0
with mesh:
    a = flat(x)
    b = hier(x)
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
# odd leading dim -> fallback path must also be exact
y = jnp.arange(8 * 5 * 3, dtype=jnp.float32).reshape(8 * 5, 3)
with mesh:
    a = flat(y)
    b = hier(y)
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_multipod_dp_trainer_matches_flat_subprocess():
    """The hierarchical (pod,data) DP trainer must produce the same losses
    as the flat data-parallel reduction."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models.api import Model
from repro.optim.adam import AdamW
from repro.optim.grad_compression import init_error_buffers
from repro.train.loop import make_dp_train_step
from repro.data.tokens import MarkovCorpus

cfg = get_config("granite-3-8b").reduced()
model = Model(cfg)
losses = {}
meshes = {"flat": jax.make_mesh((8,), ("data",)),
          "pod": jax.make_mesh((2, 4), ("pod", "data"))}
for name, mesh in meshes.items():
    corpus = MarkovCorpus(cfg.vocab_size, seed=0)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    err = init_error_buffers(params)
    step = jax.jit(make_dp_train_step(model, opt, mesh))
    ls = []
    for i in range(3):
        batch = jax.tree_util.tree_map(jnp.asarray, corpus.batch(16, 16))
        with mesh:
            params, opt_state, err, m = step(params, opt_state, err, batch)
        ls.append(float(m["loss"]))
    losses[name] = ls
assert np.allclose(losses["flat"], losses["pod"], rtol=1e-4), losses
print("OK")
"""
    import subprocess
    import sys
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr
