"""Pipeline parallelism: PP execution == sequential execution (fwd + grad).

Runs in a subprocess with 4 forced host devices (stage axis of 4).
"""
import subprocess
import sys

import pytest

from repro.distributed.pipeline import bubble_fraction, split_stages


def test_split_stages_shapes():
    import jax.numpy as jnp
    p = {"w": jnp.zeros((8, 3, 5))}
    out = split_stages(p, 4)
    assert out["w"].shape == (4, 2, 3, 5)


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == 3 / 15
    assert bubble_fraction(1, 8) == 0.0


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.pipeline import pipeline_apply, split_stages

mesh = jax.make_mesh((4,), ("stage",))
L, D = 8, 32          # 8 layers -> 4 stages x 2 layers
n_micro, B, S = 6, 2, 4

key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (L, D, D)) * (1.0 / np.sqrt(D)),
          "b": jnp.zeros((L, D))}

def layer(w, b, x):
    return jnp.tanh(x @ w + b)

def block_fn(stage_params, x):
    def body(h, wb):
        w, b = wb
        return layer(w, b, h), None
    h, _ = jax.lax.scan(body, x, (stage_params["w"], stage_params["b"]))
    return h

def sequential(params, xs):
    def body(h, wb):
        w, b = wb
        return layer(w, b, h), None
    out = []
    for i in range(xs.shape[0]):
        h, _ = jax.lax.scan(body, xs[i], (params["w"], params["b"]))
        out.append(h)
    return jnp.stack(out)

xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, S, D))
staged = split_stages(params, 4)

with mesh:
    out_pp = pipeline_apply(mesh, "stage", block_fn, staged, xs)
out_seq = sequential(params, xs)
print("fwd max diff", float(jnp.abs(out_pp - out_seq).max()))
assert float(jnp.abs(out_pp - out_seq).max()) < 1e-5

# gradients THROUGH the pipeline == sequential gradients
def loss_pp(staged):
    with mesh:
        return jnp.sum(pipeline_apply(mesh, "stage", block_fn, staged,
                                      xs) ** 2)

def loss_seq(params):
    return jnp.sum(sequential(params, xs) ** 2)

g_pp = jax.grad(loss_pp)(staged)
g_seq = jax.grad(loss_seq)(params)
gw_pp = g_pp["w"].reshape(L, D, D)
diff = float(jnp.abs(gw_pp - g_seq["w"]).max())
rel = diff / float(jnp.abs(g_seq["w"]).max())
print("grad rel diff", rel)
assert rel < 1e-4
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr
