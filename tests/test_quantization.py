import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.quantization import (QuantParams, dequantize,
                                     int_dtype_for_bits, quantize_with,
                                     quantization_snr_db, symmetric_quantize)


def test_int_dtype_selection():
    assert int_dtype_for_bits(8) == jnp.int8
    assert int_dtype_for_bits(12) == jnp.int16
    assert int_dtype_for_bits(32) == jnp.int32
    with pytest.raises(ValueError):
        int_dtype_for_bits(64)


@pytest.mark.parametrize("bits", [8, 16])
def test_roundtrip_error_bounded(bits):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.uniform(-3, 3, size=(64, 16)).astype(np.float32))
    q, p = symmetric_quantize(x, bits=bits)
    err = np.abs(np.asarray(dequantize(q, p)) - np.asarray(x))
    assert err.max() <= float(p.scale) * 0.5 + 1e-6


def test_per_channel_scales():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.uniform(-1, 1, size=(32, 4)).astype(np.float32)
                    * np.array([1, 10, 100, 1000], np.float32))
    q, p = symmetric_quantize(x, bits=8, axis=1)
    assert p.scale.shape == (1, 4)
    # each column uses its own full dynamic range
    assert np.abs(np.asarray(q)).max(axis=0).min() >= 100


def test_quantize_with_reuses_params():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.uniform(-1, 1, size=(128,)).astype(np.float32))
    _, p = symmetric_quantize(x, bits=8)
    q2 = quantize_with(x, p)
    assert np.array_equal(np.asarray(q2),
                          np.asarray(symmetric_quantize(x, 8)[0]))


def test_snr_improves_with_bits():
    rng = np.random.RandomState(3)
    x = rng.uniform(-1, 1, size=4096).astype(np.float32)
    snr8 = quantization_snr_db(x, 8)
    snr16 = quantization_snr_db(x, 16)
    assert snr8 > 30          # ~6 dB/bit rule of thumb
    assert snr16 > snr8 + 35


def test_quantparams_is_pytree():
    import jax
    _, p = symmetric_quantize(jnp.ones(4), bits=8)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 1
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert p2.bits == p.bits
