#!/usr/bin/env bash
# Fast CI tier (~1 min): the PIM-ML core — session/dataset/registry API,
# execution model, numerics, metrics — plus the kernel tier's dispatch
# parity (interpret-mode Pallas vs jnp-ref), the small-shape kernel
# cases, the job-scheduler core (allocator/slices/queue/failure
# isolation), the elastic runtime (preempt/resume bit-identity,
# migration matrix, fault injection, crash-resume; sustained churn is
# @slow), the step-fusion engine (fused-vs-serial bit parity, the
# one-launch-per-chunk assertion, chunk-pipeline depth bit-identity),
# the async training service (serve/shutdown lifecycle, SLO admission,
# deadline policy, manifest spool; the Poisson soak and the CLI serve
# run are @slow), the backend-portable System protocol
# (PIM/host/modeled-GPU parity, mixed-target scheduling), the
# telemetry layer (tracer overhead contract, Chrome-trace schema +
# determinism, metrics attribution, drift accounting; the end-to-end
# --trace CLI runs are @slow), the
# hierarchical topology/cost model + contention-aware placement
# (calibration ratio checks are fast; the large Fig. 12 sweeps are
# @slow), the EMB embedding family (sparse gather/scatter-add parity,
# ShardedTable placement, deferred-update bit-identities, compressed
# flushes, spool priority lane + sidecar replay; the three-system
# compare run and the bench-scale traffic claim are @slow), and the
# legacy deprecation surface; large-shape kernel
# cases, large-K queues, fused-sweep execution, long fused runs, and
# the full compare driver are marked @slow.
# The LM-stack breadth (arch smoke matrix, serving, multi-device
# subprocess equivalence) and the quality reproduction run in the full
# tier-1 suite: `make test` / plain pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" \
    tests/test_api.py \
    tests/test_collectives.py \
    tests/test_deprecation.py \
    tests/test_dispatch.py \
    tests/test_elastic.py \
    tests/test_emb.py \
    tests/test_estimators.py \
    tests/test_fixed_point.py \
    tests/test_kernels.py \
    tests/test_lut.py \
    tests/test_metrics.py \
    tests/test_obs.py \
    tests/test_pim_system.py \
    tests/test_quantization.py \
    tests/test_sched.py \
    tests/test_service.py \
    tests/test_sgd_and_loader.py \
    tests/test_step_fusion.py \
    tests/test_systems.py \
    tests/test_topology.py \
    "$@"
